//! Property-based tests (hand-rolled generator loops on our PRNG — no
//! proptest crate in the offline set): randomized invariants over the
//! sparse formats, kernels, prox operators, checkpoints, and data
//! pipeline. Each property runs against many random instances.

use proxcomp::runtime::{ParamBundle, ParamSpec};
use proxcomp::sparse::dispatch::{self, DynSparseMatrix, SparseFormat};
use proxcomp::sparse::{ops, prox, BlockEllMatrix, CooMatrix, CsrMatrix, DiaMatrix, EllMatrix};
use proxcomp::tensor::{matmul, matmul_nt, Tensor};
use proxcomp::util::rng::Rng;

const CASES: usize = 40;

fn random_dense(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| {
            if rng.uniform() < density {
                rng.normal() as f32
            } else {
                0.0
            }
        })
        .collect()
}

#[test]
fn prop_all_formats_roundtrip_dense() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let rows = 1 + rng.below(24);
        let cols = 1 + rng.below(24);
        let density = rng.uniform();
        let dense = random_dense(&mut rng, rows, cols, density);
        assert_eq!(CsrMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "csr case {case}");
        assert_eq!(CooMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "coo case {case}");
        assert_eq!(EllMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "ell case {case}");
        assert_eq!(DiaMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "dia case {case}");
    }
}

#[test]
fn prop_format_conversions_commute() {
    let mut rng = Rng::new(101);
    for _ in 0..CASES {
        let rows = 1 + rng.below(16);
        let cols = 1 + rng.below(16);
        let dense = random_dense(&mut rng, rows, cols, 0.3);
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        // csr -> coo -> csr is the identity.
        assert_eq!(CooMatrix::from_csr(&csr).to_csr(), csr);
        // ell built from csr or dense agree.
        assert_eq!(EllMatrix::from_csr(&csr), EllMatrix::from_dense(&dense, rows, cols));
    }
}

#[test]
fn prop_csr_transpose_involution_and_validity() {
    let mut rng = Rng::new(102);
    for _ in 0..CASES {
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(20);
        let dense = random_dense(&mut rng, rows, cols, 0.25);
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        let t = csr.transpose();
        t.validate().unwrap();
        assert_eq!(t.transpose(), csr);
        assert_eq!(t.nnz(), csr.nnz());
    }
}

#[test]
fn prop_dxct_equals_dense_matmul() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        let b = 1 + rng.below(12);
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let wd = random_dense(&mut rng, n, k, 0.3);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let got = ops::dxct(&d, &csr);
        let want = matmul_nt(&d, &Tensor::new(vec![n, k], wd));
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_dxc_equals_dense_matmul() {
    let mut rng = Rng::new(104);
    for _ in 0..CASES {
        let b = 1 + rng.below(12);
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let wd = random_dense(&mut rng, n, k, 0.3);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
        let got = ops::dxc(&g, &csr);
        let want = matmul(&g, &Tensor::new(vec![n, k], wd));
        for (a, w) in got.data.iter().zip(&want.data) {
            assert!((a - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_forward_backward_adjoint() {
    // <dxct(x, W), g> == <x, dxc(g, W)> — the VJP identity that makes the
    // Figure-2/Figure-3 pair a valid forward/backward couple.
    let mut rng = Rng::new(105);
    for _ in 0..CASES {
        let b = 1 + rng.below(8);
        let n = 1 + rng.below(20);
        let k = 1 + rng.below(20);
        let wd = random_dense(&mut rng, n, k, 0.4);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let x = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
        let fwd = ops::dxct(&x, &csr);
        let bwd = ops::dxc(&g, &csr);
        let lhs: f64 = fwd.data.iter().zip(&g.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.data.iter().zip(&bwd.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let denom = lhs.abs().max(rhs.abs()).max(1.0);
        assert!((lhs - rhs).abs() / denom < 1e-4, "{lhs} vs {rhs}");
    }
}

#[test]
fn prop_blockell_matmul_equals_dense() {
    let mut rng = Rng::new(106);
    for _ in 0..20 {
        let n_br = 1 + rng.below(5);
        let n_bc = 1 + rng.below(5);
        let (bh, bw) = (4, 8);
        let (rows, cols) = (n_br * bh, n_bc * bw);
        let dense = random_dense(&mut rng, rows, cols, 0.3);
        let bell = BlockEllMatrix::from_dense(&dense, rows, cols, bh, bw);
        assert_eq!(bell.to_dense(), dense);
        let b = 1 + rng.below(10);
        let d = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
        let got = bell.dxct(&d);
        let want = matmul_nt(&d, &Tensor::new(vec![rows, cols], dense));
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_prox_shrinkage_and_zero_band() {
    let mut rng = Rng::new(107);
    for _ in 0..CASES {
        let n = 1 + rng.below(500);
        let t = rng.range(0.0, 1.5);
        let xs: Vec<f32> = rng.normal_vec(n, 1.0);
        let mut out = xs.clone();
        prox::soft_threshold_inplace(&mut out, t);
        for (x, y) in xs.iter().zip(&out) {
            if x.abs() <= t {
                assert_eq!(*y, 0.0);
            } else {
                assert!((y.abs() - (x.abs() - t)).abs() < 1e-5);
                assert_eq!(y.signum(), x.signum());
            }
        }
    }
}

#[test]
fn prop_hard_threshold_subset_of_soft_zeros() {
    // Hard and soft thresholding zero exactly the same entries; soft
    // additionally shrinks survivors.
    let mut rng = Rng::new(108);
    for _ in 0..CASES {
        let xs: Vec<f32> = rng.normal_vec(200, 1.0);
        let t = rng.range(0.0, 1.0);
        let mut soft = xs.clone();
        let mut hard = xs.clone();
        prox::soft_threshold_inplace(&mut soft, t);
        prox::hard_threshold_inplace(&mut hard, t);
        for (s, h) in soft.iter().zip(&hard) {
            assert_eq!(*s == 0.0, *h == 0.0);
        }
    }
}

#[test]
fn prop_compression_rate_equals_explicit_zero_count() {
    let mut rng = Rng::new(109);
    for _ in 0..CASES {
        let n = 10 + rng.below(500);
        let spec = ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![n],
            prunable: true,
            layer: "fc".into(),
        };
        let mut values = rng.normal_vec(n, 1.0);
        let t = rng.range(0.0, 1.0);
        prox::soft_threshold_inplace(&mut values, t);
        let explicit = values.iter().filter(|&&v| v == 0.0).count();
        let bundle = ParamBundle { specs: vec![spec], values: vec![values] };
        assert_eq!(bundle.zero_weights(), explicit);
        assert!((bundle.compression_rate() - explicit as f64 / n as f64).abs() < 1e-12);
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_sparsity() {
    let mut rng = Rng::new(110);
    let dir = std::env::temp_dir().join("proxcomp_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..15 {
        let n = 2 + rng.below(20);
        let k = 2 + rng.below(20);
        let spec = ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![n, k],
            prunable: true,
            layer: "fc".into(),
        };
        let mut values = rng.normal_vec(n * k, 1.0);
        let t = rng.range(0.0, 2.5);
        prox::soft_threshold_inplace(&mut values, t);
        let bundle = ParamBundle { specs: vec![spec], values: vec![values] };
        let path = dir.join(format!("c{case}.pxcp"));
        proxcomp::checkpoint::save(&path, &bundle, &proxcomp::util::json::Json::obj()).unwrap();
        let ck = proxcomp::checkpoint::load(&path).unwrap();
        assert_eq!(ck.params.values, bundle.values, "case {case}");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    use proxcomp::util::json::{self, Json};
    let mut rng = Rng::new(111);

    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 128.0).round() / 128.0),
            3 => Json::Str(format!("s{}✓\n\"{}\"", rng.below(1000), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    for _ in 0..60 {
        let doc = gen(&mut rng, 3);
        let compact = json::parse(&doc.to_string_compact()).unwrap();
        let pretty = json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(doc, compact);
        assert_eq!(doc, pretty);
    }
}

#[test]
fn prop_dataset_batches_always_in_range() {
    use proxcomp::data::{self, Batcher};
    let mut rng = Rng::new(112);
    for _ in 0..8 {
        let n = 10 + rng.below(60);
        let d = data::synth_mnist(n, rng.next_u64());
        let mut b = Batcher::new(d.n, rng.next_u64());
        for _ in 0..5 {
            let batch = 1 + rng.below(17);
            let (xs, ys) = b.next_batch(&d, batch);
            assert_eq!(xs.len(), batch * 784);
            assert_eq!(ys.len(), batch);
            assert!(ys.iter().all(|&y| (0..10).contains(&y)));
            assert!(xs.iter().all(|v| v.is_finite()));
        }
    }
}

// ---------------------------------------------------------------------------
// Format dispatch (sparse::dispatch)
// ---------------------------------------------------------------------------

/// Random banded matrix: a contiguous band of `band` diagonals around the
/// main diagonal, fully populated.
fn random_banded(rng: &mut Rng, n: usize, band: usize) -> Vec<f32> {
    let mut dense = vec![0.0f32; n * n];
    let half = band as i64 / 2;
    for r in 0..n {
        for off in -half..=half {
            let c = r as i64 + off;
            if c >= 0 && (c as usize) < n {
                dense[r * n + c as usize] = rng.normal() as f32 * 0.5;
            }
        }
    }
    dense
}

/// Exactly `per_row` nonzeros per row at random distinct columns.
fn random_uniform_rows(rng: &mut Rng, rows: usize, cols: usize, per_row: usize) -> Vec<f32> {
    let mut dense = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut placed = 0;
        while placed < per_row {
            let c = rng.below(cols);
            if dense[r * cols + c] == 0.0 {
                dense[r * cols + c] = rng.normal() as f32 * 0.5;
                placed += 1;
            }
        }
    }
    dense
}

/// Fixed number of dense 8×16 tiles per block-row, scattered columns.
fn random_block_sparse(rng: &mut Rng, rows: usize, cols: usize, blocks_per_row: usize) -> Vec<f32> {
    let (bh, bw) = (dispatch::BLOCK_H, dispatch::BLOCK_W);
    let mut dense = vec![0.0f32; rows * cols];
    let n_bc = cols / bw;
    for i in 0..rows / bh {
        let mut placed = 0;
        let mut used = vec![false; n_bc];
        while placed < blocks_per_row {
            let j = rng.below(n_bc);
            if used[j] {
                continue;
            }
            used[j] = true;
            placed += 1;
            for y in 0..bh {
                for x in 0..bw {
                    dense[(i * bh + y) * cols + j * bw + x] = rng.normal() as f32 * 0.5;
                }
            }
        }
    }
    dense
}

fn chosen_format(dense: &[f32], rows: usize, cols: usize) -> SparseFormat {
    let s = dispatch::analyze(dense, rows, cols);
    dispatch::select_format(rows, cols, s.nnz, &s)
}

#[test]
fn prop_select_format_matches_structure() {
    let mut rng = Rng::new(120);
    for _ in 0..10 {
        // Banded → DIA.
        let n = 16 + 8 * rng.below(6);
        let banded = random_banded(&mut rng, n, 3);
        assert_eq!(chosen_format(&banded, n, n), SparseFormat::Dia);

        // Uniform row populations, scattered columns → ELL.
        let (rows, cols) = (32 + 8 * rng.below(4), 48 + 16 * rng.below(4));
        let uniform = random_uniform_rows(&mut rng, rows, cols, 4 + rng.below(4));
        assert_eq!(chosen_format(&uniform, rows, cols), SparseFormat::Ell);

        // Skewed rows (one dense row) → CSR. Odd cols keep Block-ELL out.
        let cols = 91;
        let mut skewed = vec![0.0f32; 24 * cols];
        for c in 0..cols {
            skewed[c] = 1.0;
        }
        for r in 1..24 {
            skewed[r * cols + rng.below(cols)] = 2.0;
        }
        assert_eq!(chosen_format(&skewed, 24, cols), SparseFormat::Csr);

        // Block-structured → Block-ELL.
        let block = random_block_sparse(&mut rng, 64, 128, 2);
        assert_eq!(chosen_format(&block, 64, 128), SparseFormat::BlockEll);
    }
}

#[test]
fn prop_every_format_roundtrips_identically() {
    // Acceptance: every format reproduces `to_dense` bit-identically on
    // the same input, whatever the structure.
    let mut rng = Rng::new(121);
    for case in 0..12 {
        let dense = match case % 3 {
            0 => random_banded(&mut rng, 32, 5),
            1 => random_uniform_rows(&mut rng, 32, 64, 5),
            _ => random_block_sparse(&mut rng, 32, 64, 2),
        };
        let (rows, cols) = (32, dense.len() / 32);
        for fmt in [
            SparseFormat::Dia,
            SparseFormat::Ell,
            SparseFormat::Csr,
            SparseFormat::Coo,
            SparseFormat::BlockEll,
        ] {
            let m = DynSparseMatrix::from_dense_as(fmt, &dense, rows, cols);
            assert_eq!(m.to_dense(), dense, "case {case}: {} roundtrip", fmt.name());
            assert_eq!(m.nnz(), dense.iter().filter(|&&v| v != 0.0).count());
        }
    }
}

#[test]
fn prop_dispatch_spmm_matches_dense_reference() {
    // Acceptance: dispatch-chosen SpMM matches the dense reference within
    // 1e-5 (relative to the magnitude of the entry) on random banded /
    // uniform / block-sparse matrices.
    let mut rng = Rng::new(122);
    for case in 0..12 {
        let (dense, rows, cols) = match case % 3 {
            0 => (random_banded(&mut rng, 40, 5), 40, 40),
            1 => (random_uniform_rows(&mut rng, 32, 48, 6), 32, 48),
            _ => (random_block_sparse(&mut rng, 32, 64, 2), 32, 64),
        };
        let m = DynSparseMatrix::from_dense(&dense, rows, cols);
        let b = 1 + rng.below(9);
        let d = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
        let got = m.dxct(&d);
        let want = matmul_nt(&d, &Tensor::new(vec![rows, cols], dense));
        for (g, w) in got.data.iter().zip(&want.data) {
            let tol = 1e-5f32 * w.abs().max(1.0);
            assert!(
                (g - w).abs() <= tol,
                "case {case} ({}): {g} vs {w}",
                m.format().name()
            );
        }
    }
}

/// The manifest-shaped MLP parameter spec used by the engine tests.
fn mlp_specs() -> Vec<ParamSpec> {
    vec![
        ParamSpec::new("fc1_w", "fc_w", vec![256, 784], true),
        ParamSpec::new("fc1_b", "fc_b", vec![256], false),
        ParamSpec::new("fc2_w", "fc_w", vec![128, 256], true),
        ParamSpec::new("fc2_b", "fc_b", vec![128], false),
        ParamSpec::new("fc3_w", "fc_w", vec![10, 128], true),
        ParamSpec::new("fc3_b", "fc_b", vec![10], false),
    ]
}

#[test]
fn prop_engine_auto_matches_dense_and_csr() {
    use proxcomp::inference::{Engine, WeightMode};
    let mut rng = Rng::new(123);
    let specs = mlp_specs();
    for _ in 0..4 {
        let mut bundle = ParamBundle::he_init(&specs, rng.next_u64());
        let t = rng.range(0.02, 0.08);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                prox::soft_threshold_inplace(v, t);
            }
        }
        let dense = Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Dense).build().unwrap();
        let csr = Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build().unwrap();
        let auto = Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Auto).build().unwrap();
        // Every weight layer got a concrete sparse format.
        for (layer, fmt) in auto.layer_formats() {
            assert_ne!(fmt, "dense", "{layer} not compressed in Auto mode");
        }
        // Auto never stores more bytes than fixed CSR (the cost model
        // only moves away from CSR when it is a strict win).
        assert!(auto.model_size_bytes() <= csr.model_size_bytes());
        let x = Tensor::new(vec![3, 1, 28, 28], rng.normal_vec(3 * 784, 1.0));
        let a = dense.forward(&x).unwrap();
        let b = auto.forward(&x).unwrap();
        for (u, v) in a.data.iter().zip(&b.data) {
            assert!((u - v).abs() < 1e-3, "dense/auto engines diverge: {u} vs {v}");
        }
    }
}

// ---------------------------------------------------------------------------
// Thread-count determinism (serving-path guarantee)
// ---------------------------------------------------------------------------

/// Exact-bits comparison: `f32` equality would conflate +0.0 / -0.0 and
/// hide NaNs, and the determinism contract is *bit*-identity.
fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: element {i}: {x} vs {y}");
    }
}

#[test]
fn prop_format_kernels_bit_identical_across_thread_counts() {
    // Every format's dxct must produce bit-identical output whether it
    // runs inline (1 thread) or wide (8 threads), at serving batch sizes
    // (1) and mid sizes that flip the partition axis.
    let mut rng = Rng::new(130);
    for case in 0..8 {
        let (dense, rows, cols) = match case % 4 {
            0 => (random_banded(&mut rng, 48, 5), 48, 48),
            1 => (random_uniform_rows(&mut rng, 40, 64, 5), 40, 64),
            2 => (random_block_sparse(&mut rng, 32, 64, 2), 32, 64),
            _ => (random_dense(&mut rng, 37, 53, 0.07), 37, 53),
        };
        for fmt in [
            SparseFormat::Dia,
            SparseFormat::Ell,
            SparseFormat::Csr,
            SparseFormat::Coo,
            SparseFormat::BlockEll,
        ] {
            if fmt == SparseFormat::BlockEll && (rows % dispatch::BLOCK_H != 0 || cols % dispatch::BLOCK_W != 0) {
                continue;
            }
            let m = DynSparseMatrix::from_dense_as(fmt, &dense, rows, cols);
            for b in [1usize, 3, 9] {
                let d = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
                let one = m.dxct_threads(&d, 1);
                for threads in [2usize, 4, 8] {
                    let wide = m.dxct_threads(&d, threads);
                    assert_bits_eq(
                        &one.data,
                        &wide.data,
                        &format!("{} b={b} threads={threads}", fmt.name()),
                    );
                }
            }
        }
    }
}

#[test]
fn prop_csr_scalar_paths_bit_identical_across_thread_counts() {
    // The remaining CSR scalar kernels: dxct_scalar (whose small-batch
    // arm switches to an output-column partition), dxc_scalar, cxd, spmv.
    let mut rng = Rng::new(131);
    for _ in 0..12 {
        let n = 1 + rng.below(40);
        let k = 1 + rng.below(40);
        let dense = random_dense(&mut rng, n, k, 0.15);
        let csr = CsrMatrix::from_dense(&dense, n, k);
        for b in [1usize, 2, 5, 11] {
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
            let fwd1 = ops::dxct_scalar_threads(&d, &csr, 1);
            let bwd1 = ops::dxc_scalar_threads(&g, &csr, 1);
            for threads in [2usize, 8] {
                assert_bits_eq(
                    &fwd1.data,
                    &ops::dxct_scalar_threads(&d, &csr, threads).data,
                    &format!("dxct_scalar b={b} t={threads}"),
                );
                assert_bits_eq(
                    &bwd1.data,
                    &ops::dxc_scalar_threads(&g, &csr, threads).data,
                    &format!("dxc_scalar b={b} t={threads}"),
                );
            }
        }
        let dm = Tensor::new(vec![k, 6], rng.normal_vec(k * 6, 1.0));
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        assert_bits_eq(
            &ops::cxd_threads(&csr, &dm, 1).data,
            &ops::cxd_threads(&csr, &dm, 8).data,
            "cxd",
        );
        assert_bits_eq(&ops::spmv_threads(&csr, &x, 1), &ops::spmv_threads(&csr, &x, 8), "spmv");
    }
}

// ---------------------------------------------------------------------------
// Blocked kernel family (§Blocked reduction contract in sparse::ops)
// ---------------------------------------------------------------------------
//
// These tests pin the kernels to an *independent* re-implementation of
// the documented per-element reduction: blocked mode puts nonzero `q` in
// lane `q % LANES` and collapses through the fixed tree; scalar mode
// sums ascending-index. They read `PROXCOMP_KERNEL` (never write it, so
// they stay race-free under the parallel test runner): the default CI
// leg exercises the blocked family, the `PROXCOMP_KERNEL=scalar` matrix
// leg the sequential one.

/// The documented lane tree, written out by hand so the oracle does not
/// depend on `pool::tree_reduce` being correct.
fn lane_tree(acc: [f32; proxcomp::util::pool::LANES]) -> f32 {
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    (s0 + s2) + (s1 + s3)
}

/// Reference row dot for whichever kernel family the environment selects.
fn oracle_row_dot(
    mode: proxcomp::util::pool::KernelMode,
    dvec: &[f32],
    indices: &[u32],
    data: &[f32],
) -> f32 {
    use proxcomp::util::pool::{KernelMode, LANES};
    match mode {
        KernelMode::Blocked => {
            let mut acc = [0.0f32; LANES];
            for (q, (i, v)) in indices.iter().zip(data).enumerate() {
                acc[q % LANES] += v * dvec[*i as usize];
            }
            lane_tree(acc)
        }
        KernelMode::Scalar => {
            let mut acc = 0.0f32;
            for (i, v) in indices.iter().zip(data) {
                acc += v * dvec[*i as usize];
            }
            acc
        }
    }
}

/// Heavy-tailed fixture: row 0 near-dense, every third row empty, the
/// rest sparse — the EIE row-skew shape the nnz-prefix partition exists
/// for, plus the empty-row edge case.
fn random_skewed(rng: &mut Rng, rows: usize, cols: usize) -> Vec<f32> {
    let mut dense = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let density = if r == 0 {
            0.9
        } else if r % 3 == 0 {
            0.0
        } else {
            0.05
        };
        for c in 0..cols {
            if rng.uniform() < density {
                dense[r * cols + c] = rng.normal() as f32;
            }
        }
    }
    dense
}

#[test]
fn prop_csr_kernels_match_family_oracle_bitwise() {
    use proxcomp::util::pool::{kernel_mode, LANES};
    assert_eq!(LANES, 8, "the hand-written oracle tree assumes 8 lanes");
    let mode = kernel_mode();
    let mut rng = Rng::new(150);
    let fixtures: Vec<(Vec<f32>, usize, usize)> = vec![
        (random_skewed(&mut rng, 24, 40), 24, 40), // skewed + empty rows
        (random_dense(&mut rng, 1, 33, 0.5), 1, 33), // single row
        (vec![0.0; 6 * 9], 6, 9),                  // every row empty
        (random_dense(&mut rng, 19, 64, 0.9), 19, 64), // long rows: full lane blocks + tail
        (random_dense(&mut rng, 40, 7, 0.2), 40, 7), // short rows: tail only
    ];
    for (fi, (dense, n, k)) in fixtures.iter().enumerate() {
        let (n, k) = (*n, *k);
        let csr = CsrMatrix::from_dense(dense, n, k);
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let got = ops::spmv_threads(&csr, &x, 3);
        for r in 0..n {
            let (lo, hi) = (csr.ptr[r], csr.ptr[r + 1]);
            let want = oracle_row_dot(mode, &x, &csr.indices[lo..hi], &csr.data[lo..hi]);
            assert_eq!(got[r].to_bits(), want.to_bits(), "fixture {fi} spmv row {r}");
        }
        // dxct below and above SPMM_MIN_BATCH: the gathered-dot path and
        // the lane-plane SpMM path must both realize the same
        // per-element reduction the oracle spells out.
        for b in [1usize, 2, ops::SPMM_MIN_BATCH + 1] {
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = ops::dxct_threads(&d, &csr, 4);
            for bi in 0..b {
                let drow = &d.data[bi * k..(bi + 1) * k];
                for col in 0..n {
                    let (lo, hi) = (csr.ptr[col], csr.ptr[col + 1]);
                    let want =
                        oracle_row_dot(mode, drow, &csr.indices[lo..hi], &csr.data[lo..hi]);
                    assert_eq!(
                        got.data[bi * n + col].to_bits(),
                        want.to_bits(),
                        "fixture {fi} dxct b={b} bi={bi} col={col}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_qcs_kernels_match_family_oracle_bitwise() {
    // Same oracle, quantized storage: the dequantized-CSR twin exposes
    // the identical (index, value) sequence per row, so the QCS kernels
    // must hit the oracle bit-for-bit too.
    use proxcomp::quant::{QcsMatrix, QuantConfig};
    use proxcomp::util::pool::kernel_mode;
    let mode = kernel_mode();
    let mut rng = Rng::new(151);
    for fi in 0..6 {
        let (n, k) = (1 + rng.below(30), 1 + rng.below(40));
        let dense = if fi == 0 {
            random_skewed(&mut rng, n, k)
        } else {
            random_dense(&mut rng, n, k, 0.3)
        };
        let q = QcsMatrix::from_dense(&dense, n, k, &QuantConfig::default());
        let csr = q.to_csr();
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let got = q.spmv_threads(&x, 2);
        for r in 0..n {
            let (lo, hi) = (csr.ptr[r], csr.ptr[r + 1]);
            let want = oracle_row_dot(mode, &x, &csr.indices[lo..hi], &csr.data[lo..hi]);
            assert_eq!(got[r].to_bits(), want.to_bits(), "fixture {fi} qcs spmv row {r}");
        }
        let b = 1 + rng.below(4);
        let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let got = q.dxct_threads(&d, 3);
        for bi in 0..b {
            let drow = &d.data[bi * k..(bi + 1) * k];
            for col in 0..n {
                let (lo, hi) = (csr.ptr[col], csr.ptr[col + 1]);
                let want = oracle_row_dot(mode, drow, &csr.indices[lo..hi], &csr.data[lo..hi]);
                assert_eq!(
                    got.data[bi * n + col].to_bits(),
                    want.to_bits(),
                    "fixture {fi} qcs dxct bi={bi} col={col}"
                );
            }
        }
    }
}

#[test]
fn prop_native_fc_kernels_match_family_oracle_bitwise() {
    // The dense twins: fc_forward's row dot puts element kk in lane
    // kk % LANES (bias added after the tree); fc_grad_x puts term o in
    // lane o % LANES. Scalar mode sums sequentially (forward starts from
    // the bias).
    use proxcomp::runtime::native;
    use proxcomp::util::pool::{kernel_mode, KernelMode, LANES};
    let mode = kernel_mode();
    let mut rng = Rng::new(152);
    for (b, k, n) in [(1usize, 5usize, 3usize), (2, 16, 9), (4, 33, 17), (9, 20, 11)] {
        let x = rng.normal_vec(b * k, 1.0);
        let w = rng.normal_vec(n * k, 1.0);
        let bias = rng.normal_vec(n, 1.0);
        let y = native::fc_forward(&x, b, k, &w, &bias, n, 2);
        for bi in 0..b {
            for o in 0..n {
                let want = match mode {
                    KernelMode::Blocked => {
                        let mut acc = [0.0f32; LANES];
                        for kk in 0..k {
                            acc[kk % LANES] += x[bi * k + kk] * w[o * k + kk];
                        }
                        bias[o] + lane_tree(acc)
                    }
                    KernelMode::Scalar => {
                        let mut acc = bias[o];
                        for kk in 0..k {
                            acc += x[bi * k + kk] * w[o * k + kk];
                        }
                        acc
                    }
                };
                assert_eq!(
                    y[bi * n + o].to_bits(),
                    want.to_bits(),
                    "fc_forward b={b} bi={bi} o={o}"
                );
            }
        }
        let dy = rng.normal_vec(b * n, 1.0);
        let dx = native::fc_grad_x(&dy, b, n, &w, k, 3);
        for bi in 0..b {
            for kk in 0..k {
                let want = match mode {
                    KernelMode::Blocked => {
                        let mut acc = [0.0f32; LANES];
                        for o in 0..n {
                            acc[o % LANES] += dy[bi * n + o] * w[o * k + kk];
                        }
                        lane_tree(acc)
                    }
                    KernelMode::Scalar => {
                        let mut acc = 0.0f32;
                        for o in 0..n {
                            acc += dy[bi * n + o] * w[o * k + kk];
                        }
                        acc
                    }
                };
                assert_eq!(
                    dx[bi * k + kk].to_bits(),
                    want.to_bits(),
                    "fc_grad_x b={b} bi={bi} kk={kk}"
                );
            }
        }
    }
}

#[test]
fn prop_dxct_batch_split_invariant_bitwise() {
    // Coalescing B single-sample requests into one (B, K) batch must not
    // change any sample's bits — this is what makes serving-path batch
    // coalescing transparent. Batches straddle SPMM_MIN_BATCH so under
    // blocked mode the check crosses the gathered-dot / SpMM-plane
    // boundary; it holds in the scalar family too.
    let mut rng = Rng::new(153);
    for case in 0..8 {
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let dense = random_dense(&mut rng, n, k, 0.3);
        let csr = CsrMatrix::from_dense(&dense, n, k);
        let b = ops::SPMM_MIN_BATCH + rng.below(8);
        let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let batched = ops::dxct_threads(&d, &csr, 4);
        for bi in 0..b {
            let row = Tensor::new(vec![1, k], d.data[bi * k..(bi + 1) * k].to_vec());
            let single = ops::dxct_threads(&row, &csr, 1);
            assert_bits_eq(
                &single.data,
                &batched.data[bi * n..(bi + 1) * n],
                &format!("case {case} bi={bi}"),
            );
        }
    }
}

#[test]
fn prop_skewed_nnz_partition_thread_determinism() {
    // The nnz-prefix partition may only move thread boundaries, never
    // bits — exercised where the boundaries actually shift relative to
    // an even row split: heavily skewed fixtures. Covers the CSR
    // serving kernels, cxd, and the QCS twins.
    use proxcomp::quant::{QcsMatrix, QuantConfig};
    let mut rng = Rng::new(154);
    for case in 0..6 {
        let n = 32 + rng.below(32);
        let k = 48;
        let dense = random_skewed(&mut rng, n, k);
        let csr = CsrMatrix::from_dense(&dense, n, k);
        let q = QcsMatrix::from_dense(&dense, n, k, &QuantConfig::default());
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let d1 = Tensor::new(vec![1, k], rng.normal_vec(k, 1.0));
        let d9 = Tensor::new(vec![9, k], rng.normal_vec(9 * k, 1.0));
        let dm = Tensor::new(vec![k, 5], rng.normal_vec(k * 5, 1.0));
        let s1 = ops::spmv_threads(&csr, &x, 1);
        let f1 = ops::dxct_threads(&d1, &csr, 1);
        let m1 = ops::dxct_threads(&d9, &csr, 1);
        let c1 = ops::cxd_threads(&csr, &dm, 1);
        let qs1 = q.spmv_threads(&x, 1);
        let qf1 = q.dxct_threads(&d1, 1);
        for t in [2usize, 3, 8] {
            let tag = |kern: &str| format!("{kern} case {case} t={t}");
            assert_bits_eq(&s1, &ops::spmv_threads(&csr, &x, t), &tag("spmv"));
            assert_bits_eq(&f1.data, &ops::dxct_threads(&d1, &csr, t).data, &tag("dxct b1"));
            assert_bits_eq(&m1.data, &ops::dxct_threads(&d9, &csr, t).data, &tag("dxct b9"));
            assert_bits_eq(&c1.data, &ops::cxd_threads(&csr, &dm, t).data, &tag("cxd"));
            assert_bits_eq(&qs1, &q.spmv_threads(&x, t), &tag("qcs spmv"));
            assert_bits_eq(&qf1.data, &q.dxct_threads(&d1, t).data, &tag("qcs dxct"));
        }
    }
}

/// Serializes the tests that flip the `PROXCOMP_THREADS` env var (it is
/// process-global; flipping it concurrently would not break determinism
/// — that is the property under test — but would muddy failure reports).
static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// Restores `PROXCOMP_THREADS` on drop, so a failing assertion between
/// the `set_var` calls cannot leak a flipped setting into the rest of
/// the test process (which would defeat the CI thread-matrix legs).
struct EnvThreadsGuard(Option<String>);

impl Drop for EnvThreadsGuard {
    fn drop(&mut self) {
        match self.0.take() {
            Some(v) => std::env::set_var("PROXCOMP_THREADS", v),
            None => std::env::remove_var("PROXCOMP_THREADS"),
        }
    }
}

#[test]
fn prop_engine_forward_bit_identical_across_env_thread_counts() {
    use proxcomp::inference::{Engine, WeightMode};
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = EnvThreadsGuard(std::env::var("PROXCOMP_THREADS").ok());
    let mut rng = Rng::new(132);
    let specs = mlp_specs();
    let mut bundle = ParamBundle::he_init(&specs, rng.next_u64());
    for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if spec.prunable {
            prox::soft_threshold_inplace(v, 0.05);
        }
    }
    for mode in [WeightMode::Csr, WeightMode::Auto] {
        let engine = Engine::builder("mlp").bundle(&bundle).mode(mode).build().unwrap();
        for b in [1usize, 3] {
            let x = Tensor::new(vec![b, 1, 28, 28], rng.normal_vec(b * 784, 1.0));
            std::env::set_var("PROXCOMP_THREADS", "1");
            let one = engine.forward(&x).unwrap();
            std::env::set_var("PROXCOMP_THREADS", "8");
            let eight = engine.forward(&x).unwrap();
            assert_bits_eq(&one.data, &eight.data, &format!("engine {mode:?} b={b}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Batched serving (inference::server)
// ---------------------------------------------------------------------------

#[test]
fn prop_batch_server_matches_per_sample_forward() {
    use proxcomp::inference::{BatchConfig, BatchServer, Engine, WeightMode};
    use std::sync::Arc;
    use std::time::Duration;
    let mut rng = Rng::new(133);
    let specs = mlp_specs();
    let mut bundle = ParamBundle::he_init(&specs, rng.next_u64());
    for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if spec.prunable {
            prox::soft_threshold_inplace(v, 0.04);
        }
    }
    let engine =
        Arc::new(Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build().unwrap());
    // max_batch 16 lets coalesced forwards cross SPMM_MIN_BATCH into the
    // column-major CSR path, so the equality also proves that path keeps
    // the per-row reduction order of the single-sample scalar path.
    for (max_batch, requests) in [(4usize, 1usize), (4, 4), (4, 11), (16, 21)] {
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(max_batch, Duration::from_millis(40), (1, 28, 28)),
        );
        let submitted: Vec<(Vec<f32>, proxcomp::inference::Pending)> = (0..requests)
            .map(|_| {
                let sample = rng.normal_vec(784, 1.0);
                let pending = server.submit(&sample).unwrap();
                (sample, pending)
            })
            .collect();
        for (sample, pending) in submitted {
            let got = pending.wait().unwrap();
            let x = Tensor::new(vec![1, 1, 28, 28], sample);
            let want = engine.forward(&x).unwrap();
            assert_bits_eq(&got, &want.data, &format!("server max_batch={max_batch}"));
        }
        let stats = server.stats();
        assert_eq!(stats.requests, requests);
        assert!(stats.max_batch <= max_batch);
        // More requests than the ceiling must split into several batches.
        assert!(
            stats.batches >= requests.div_ceil(max_batch),
            "requests {requests} ceiling {max_batch}: only {} batches",
            stats.batches
        );
    }
}

// ---------------------------------------------------------------------------
// Edge-case matrices (empty / single-row / single-column / zero rows)
// ---------------------------------------------------------------------------

#[test]
fn prop_edge_case_matrices_multiply_and_roundtrip() {
    let mut rng = Rng::new(134);
    let mut single_row = vec![0.0f32; 7];
    single_row[1] = 1.5;
    single_row[6] = -2.0;
    let mut single_col = vec![0.0f32; 6];
    single_col[0] = 3.0;
    single_col[4] = -1.0;
    let mut zero_rows = random_dense(&mut rng, 5, 6, 0.6);
    for c in 0..6 {
        zero_rows[c] = 0.0; // row 0 empty
        zero_rows[3 * 6 + c] = 0.0; // row 3 empty
    }
    let cases: [(&str, Vec<f32>, usize, usize); 4] = [
        ("empty", vec![0.0; 3 * 5], 3, 5),
        ("single-row", single_row, 1, 7),
        ("single-col", single_col, 6, 1),
        ("zero-rows", zero_rows, 5, 6),
    ];
    for (name, dense, rows, cols) in &cases {
        let (rows, cols) = (*rows, *cols);
        let csr = CsrMatrix::from_dense(dense, rows, cols);
        csr.validate().unwrap();
        let b = 2;
        let d = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
        let want = matmul_nt(&d, &Tensor::new(vec![rows, cols], dense.clone()));

        // Element formats via the dispatch constructor; Block-ELL with a
        // 1×1 tile (the edge shapes are not 8×16-tileable).
        let mut mats: Vec<(String, DynSparseMatrix)> = [
            SparseFormat::Dia,
            SparseFormat::Ell,
            SparseFormat::Csr,
            SparseFormat::Coo,
        ]
        .iter()
        .map(|&fmt| {
            (fmt.name().to_string(), DynSparseMatrix::from_dense_as(fmt, dense, rows, cols))
        })
        .collect();
        mats.push((
            "BlockELL-1x1".to_string(),
            DynSparseMatrix::BlockEll(BlockEllMatrix::from_dense(dense, rows, cols, 1, 1)),
        ));
        for (fname, m) in &mats {
            assert_eq!(&m.to_dense(), dense, "{name}: {fname} roundtrip");
            let got = m.dxct(&d);
            assert_eq!(got.shape, vec![b, rows], "{name}: {fname} shape");
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "{name}: {fname}: {g} vs {w}");
            }
            // Thread-count determinism holds on degenerate shapes too.
            assert_bits_eq(
                &m.dxct_threads(&d, 1).data,
                &m.dxct_threads(&d, 8).data,
                &format!("{name}: {fname} threads"),
            );
        }

        // CSR round-trip conversions for every format.
        let dia = DiaMatrix::from_csr(&csr);
        assert_eq!(dia.to_csr(), csr, "{name}: DIA csr roundtrip");
        let ell = EllMatrix::from_csr(&csr);
        assert_eq!(ell.to_csr(), csr, "{name}: ELL csr roundtrip");
        let coo = CooMatrix::from_csr(&csr);
        assert_eq!(coo.to_csr(), csr, "{name}: COO csr roundtrip");
        let bell = BlockEllMatrix::from_csr(&csr, 1, 1);
        assert_eq!(bell.to_csr(), csr, "{name}: BlockELL csr roundtrip");
        assert_eq!(csr.nnz(), dense.iter().filter(|&&v| v != 0.0).count(), "{name}: nnz");
    }
}

#[test]
fn prop_engine_dense_sparse_parity_random_weights() {
    use proxcomp::inference::{Engine, WeightMode};
    let mut rng = Rng::new(113);
    for _ in 0..6 {
        // Random sparse MLP bundle at the manifest shapes.
        let specs = mlp_specs();
        let mut bundle = ParamBundle::he_init(&specs, rng.next_u64());
        let t = rng.range(0.0, 0.08);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                prox::soft_threshold_inplace(v, t);
            }
        }
        let dense = Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Dense).build().unwrap();
        let sparse = Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build().unwrap();
        let x = Tensor::new(vec![3, 1, 28, 28], rng.normal_vec(3 * 784, 1.0));
        let a = dense.forward(&x).unwrap();
        let b = sparse.forward(&x).unwrap();
        for (u, v) in a.data.iter().zip(&b.data) {
            assert!((u - v).abs() < 1e-3, "dense/sparse engines diverge: {u} vs {v}");
        }
    }
}

// ---------------------------------------------------------------------------
// Native training backend (runtime::native)
// ---------------------------------------------------------------------------

#[test]
fn prop_native_prox_adam_matches_scalar_reference() {
    // The backend's vector Prox-ADAM against an independent elementwise
    // reference — bit-exact, across timesteps, rates and λ (including
    // λ=0, where the prox must be the identity).
    use proxcomp::runtime::native;
    let mut rng = Rng::new(140);
    for case in 0..CASES {
        let n = 1 + rng.below(300);
        let mut w = rng.normal_vec(n, 0.5);
        let g = rng.normal_vec(n, 1.0);
        let mut m = rng.normal_vec(n, 0.1);
        let mut v: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
        let t = (1 + rng.below(200)) as f32;
        let lr = rng.range(1e-4, 5e-2);
        let lambda = if case % 3 == 0 { 0.0 } else { rng.range(0.0, 4.0) };
        // Scalar reference, one element at a time.
        let (mut rw, mut rm, mut rv) = (w.clone(), m.clone(), v.clone());
        let (b1, b2, eps) = (native::BETA1, native::BETA2, native::EPS);
        for i in 0..n {
            rm[i] = b1 * rm[i] + (1.0 - b1) * g[i];
            rv[i] = b2 * rv[i] + (1.0 - b2) * g[i] * g[i];
            let mhat = rm[i] / (1.0 - b1.powf(t));
            let vhat = rv[i] / (1.0 - b2.powf(t));
            rw[i] -= lr * mhat / (vhat.sqrt() + eps);
            if lambda > 0.0 {
                let thresh = lr * lambda;
                let a = rw[i].abs() - thresh;
                rw[i] = if a > 0.0 { a * rw[i].signum() } else { 0.0 };
            }
        }
        native::prox_adam_update(&mut w, &g, &mut m, &mut v, t, lr, lambda);
        assert_bits_eq(&w, &rw, &format!("case {case}: weights (λ={lambda})"));
        assert_bits_eq(&m, &rm, &format!("case {case}: first moment"));
        assert_bits_eq(&v, &rv, &format!("case {case}: second moment"));
    }
}

#[test]
fn prop_native_prox_rmsprop_and_sgd_match_scalar_reference() {
    use proxcomp::runtime::native;
    let mut rng = Rng::new(141);
    for case in 0..CASES {
        let n = 1 + rng.below(200);
        let g = rng.normal_vec(n, 1.0);
        let lr = rng.range(1e-4, 5e-2);
        let lambda = rng.range(0.0, 2.0);
        // RMSProp.
        let mut w = rng.normal_vec(n, 0.5);
        let mut v: Vec<f32> = rng.normal_vec(n, 0.1).iter().map(|x| x * x).collect();
        let (mut rw, mut rv) = (w.clone(), v.clone());
        for i in 0..n {
            rv[i] = native::RMS_RHO * rv[i] + (1.0 - native::RMS_RHO) * g[i] * g[i];
            rw[i] -= lr * g[i] / (rv[i].sqrt() + native::EPS);
            if lambda > 0.0 {
                let a = rw[i].abs() - lr * lambda;
                rw[i] = if a > 0.0 { a * rw[i].signum() } else { 0.0 };
            }
        }
        native::prox_rmsprop_update(&mut w, &g, &mut v, lr, lambda);
        assert_bits_eq(&w, &rw, &format!("rmsprop case {case}"));
        assert_bits_eq(&v, &rv, &format!("rmsprop v case {case}"));
        // SGD.
        let mut w = rng.normal_vec(n, 0.5);
        let mut rw = w.clone();
        for i in 0..n {
            rw[i] -= lr * g[i];
            if lambda > 0.0 {
                let a = rw[i].abs() - lr * lambda;
                rw[i] = if a > 0.0 { a * rw[i].signum() } else { 0.0 };
            }
        }
        native::prox_sgd_update(&mut w, &g, lr, lambda);
        assert_bits_eq(&w, &rw, &format!("sgd case {case}"));
    }
}

#[test]
fn prop_native_training_bit_deterministic_across_env_thread_counts() {
    // The whole native training loop — data synthesis, batching,
    // forward, backward, Prox-ADAM, evaluate — must be bit-identical
    // under PROXCOMP_THREADS=1 and =4 (the CI thread matrix): the
    // kernels partition work but never change any reduction order.
    use proxcomp::config::RunConfig;
    use proxcomp::coordinator::{trainer::StepScalars, Trainer};
    use proxcomp::runtime::{Manifest, Runtime};
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = EnvThreadsGuard(std::env::var("PROXCOMP_THREADS").ok());
    let manifest = Manifest::native();
    let cfg = RunConfig {
        model: "mlp-s".into(),
        steps: 8,
        lambda: 1.0,
        lr: 2e-3,
        train_examples: 96,
        test_examples: 64,
        artifacts_dir: "native".into(),
        ..RunConfig::default()
    };
    let run = |threads: &str| {
        std::env::set_var("PROXCOMP_THREADS", threads);
        let mut rt = Runtime::native();
        let mut trainer = Trainer::new(&manifest, &cfg).unwrap();
        let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
        let mut losses = Vec::new();
        for _ in 0..cfg.steps {
            losses.push(trainer.step(&mut rt, "train_prox_adam", scalars).unwrap());
        }
        let eval = trainer.evaluate(&mut rt).unwrap();
        (losses, trainer.state.params.values.clone(), eval.loss, eval.accuracy)
    };
    let (losses1, params1, eloss1, eacc1) = run("1");
    let (losses4, params4, eloss4, eacc4) = run("4");
    assert_bits_eq(&losses1, &losses4, "per-step losses");
    assert_eq!(params1.len(), params4.len());
    for (i, (a, b)) in params1.iter().zip(&params4).enumerate() {
        assert_bits_eq(a, b, &format!("parameter leaf {i}"));
    }
    assert_eq!(eloss1.to_bits(), eloss4.to_bits(), "eval loss");
    assert_eq!(eacc1, eacc4, "eval accuracy");
}

// ---------------------------------------------------------------------------
// Conv training kernels (tensor::col2im / max_pool_backward + the native
// conv executor)
// ---------------------------------------------------------------------------

#[test]
fn prop_im2col_col2im_adjoint_identity() {
    // ⟨im2col(x), y⟩ = ⟨x, col2im(y)⟩ for random geometries — the
    // defining property that makes col2im(dy·W) the conv input gradient.
    use proxcomp::tensor::{col2im, im2col, ConvSpec};
    let mut rng = Rng::new(150);
    for case in 0..CASES {
        let b = 1 + rng.below(3);
        let c = 1 + rng.below(3);
        let h = 3 + rng.below(8);
        let w = 3 + rng.below(8);
        let kh = 1 + rng.below((h - 1).min(3));
        let kw = 1 + rng.below((w - 1).min(3));
        let spec = ConvSpec { stride: 1 + rng.below(2), pad: rng.below(2) };
        let x = Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w, 1.0));
        let cols = im2col(&x, kh, kw, spec);
        let y = Tensor::new(cols.shape.clone(), rng.normal_vec(cols.numel(), 1.0));
        let folded = col2im(&y, b, c, h, w, kh, kw, spec);
        let lhs: f64 = cols.data.iter().zip(&y.data).map(|(a, b)| (a * b) as f64).sum();
        let rhs: f64 = x.data.iter().zip(&folded.data).map(|(a, b)| (a * b) as f64).sum();
        assert!(
            (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
            "case {case} ({b},{c},{h},{w}) k={kh}x{kw} s={} p={}: {lhs} vs {rhs}",
            spec.stride,
            spec.pad
        );
    }
}

#[test]
fn prop_conv2d_backward_matches_finite_differences() {
    // The conv gradients the native executor assembles from the public
    // kernels — weight grad = colsᵀ·dy (fc_grad_w over im2col), input
    // grad = col2im(dy·W) (fc_grad_x then fold) — against central
    // differences of the scalar loss L = ⟨conv2d(·), r⟩, 9 directions
    // each, tolerance-pinned at `native::FD_TOL`, mirroring the MLP
    // check. The loss is linear in w (and in x), so for a correct
    // backward every direction must agree to float precision.
    use proxcomp::runtime::native;
    use proxcomp::tensor::{col2im, conv2d, im2col, ConvSpec};
    let mut rng = Rng::new(151);
    let (b, c, h, w, o, k) = (2usize, 2usize, 7usize, 7usize, 3usize, 3usize);
    let spec = ConvSpec { stride: 1, pad: 0 };
    let (oh, ow) = (5usize, 5usize);
    let (rows, kk) = (b * oh * ow, c * k * k);
    let x = Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w, 1.0));
    let wt = Tensor::new(vec![o, c, k, k], rng.normal_vec(o * kk, 0.5));
    let bias = vec![0.0f32; o];
    // Random output coefficients r, both as NCHW and (B·OH·OW, O) rows.
    let r = Tensor::new(vec![b, o, oh, ow], rng.normal_vec(b * o * oh * ow, 1.0));
    let mut r_rows = vec![0.0f32; rows * o];
    for bi in 0..b {
        for oc in 0..o {
            for oy in 0..oh {
                for ox in 0..ow {
                    r_rows[((bi * oh + oy) * ow + ox) * o + oc] =
                        r.data[((bi * o + oc) * oh + oy) * ow + ox];
                }
            }
        }
    }
    let loss_of = |x: &Tensor, wt: &Tensor| -> f32 {
        conv2d(x, wt, &bias, spec).data.iter().zip(&r.data).map(|(a, b)| a * b).sum()
    };
    let cols = im2col(&x, k, k, spec);
    let dw = native::fc_grad_w(&r_rows, rows, o, &cols.data, kk, 1);
    let dcols = native::fc_grad_x(&r_rows, rows, o, &wt.data, kk, 1);
    let dx = col2im(&Tensor::new(vec![rows, kk], dcols), b, c, h, w, k, k, spec);
    let fd = 1e-3f32;
    for dir in 0..9 {
        // Weight direction.
        let d = rng.normal_vec(o * kk, 1.0);
        let analytic: f32 = dw.iter().zip(&d).map(|(a, b)| a * b).sum();
        let shift = |sign: f32| {
            let data: Vec<f32> =
                wt.data.iter().zip(&d).map(|(v, di)| v + sign * fd * di).collect();
            Tensor::new(wt.shape.clone(), data)
        };
        let numeric = (loss_of(&x, &shift(1.0)) - loss_of(&x, &shift(-1.0))) / (2.0 * fd);
        let denom = analytic.abs().max(numeric.abs()).max(0.5);
        assert!(
            (analytic - numeric).abs() / denom < native::FD_TOL,
            "dW dir {dir}: analytic {analytic} vs numeric {numeric}"
        );
        // Input direction.
        let d = rng.normal_vec(b * c * h * w, 1.0);
        let analytic: f32 = dx.data.iter().zip(&d).map(|(a, b)| a * b).sum();
        let shift = |sign: f32| {
            let data: Vec<f32> =
                x.data.iter().zip(&d).map(|(v, di)| v + sign * fd * di).collect();
            Tensor::new(x.shape.clone(), data)
        };
        let numeric = (loss_of(&shift(1.0), &wt) - loss_of(&shift(-1.0), &wt)) / (2.0 * fd);
        let denom = analytic.abs().max(numeric.abs()).max(0.5);
        assert!(
            (analytic - numeric).abs() / denom < native::FD_TOL,
            "dX dir {dir}: analytic {analytic} vs numeric {numeric}"
        );
    }
}

#[test]
fn prop_max_pool_backward_matches_finite_differences() {
    // L = ⟨max_pool(x), r⟩: the analytic dx routes r to each window's
    // argmax; away from ties (random continuous inputs) the central
    // difference must agree per the 9-direction supermajority rule —
    // a direction can step across an argmax switch, so we tolerate the
    // same minority of kink hits the MLP/conv checks do.
    use proxcomp::runtime::native;
    use proxcomp::tensor::{max_pool, max_pool_backward};
    let mut rng = Rng::new(152);
    for (h, size, stride) in [(8usize, 2usize, 2usize), (7, 3, 2), (9, 2, 1)] {
        let (b, c) = (2usize, 2usize);
        let x = Tensor::new(vec![b, c, h, h], rng.normal_vec(b * c * h * h, 1.0));
        let pooled = max_pool(&x, size, stride);
        let r = Tensor::new(pooled.shape.clone(), rng.normal_vec(pooled.numel(), 1.0));
        let dx = max_pool_backward(&x, &r, size, stride);
        let loss_of = |x: &Tensor| -> f32 {
            max_pool(x, size, stride).data.iter().zip(&r.data).map(|(a, b)| a * b).sum()
        };
        let fd = 1e-3f32;
        let mut ok = 0;
        for _ in 0..9 {
            let d = rng.normal_vec(x.numel(), 1.0);
            let analytic: f32 = dx.data.iter().zip(&d).map(|(a, b)| a * b).sum();
            let shift = |sign: f32| {
                let data: Vec<f32> =
                    x.data.iter().zip(&d).map(|(v, di)| v + sign * fd * di).collect();
                Tensor::new(x.shape.clone(), data)
            };
            let numeric = (loss_of(&shift(1.0)) - loss_of(&shift(-1.0))) / (2.0 * fd);
            let denom = analytic.abs().max(numeric.abs()).max(0.5);
            if (analytic - numeric).abs() / denom < native::FD_TOL {
                ok += 1;
            }
        }
        assert!(
            ok >= native::FD_MIN_AGREE,
            "pool {size}/{stride} on {h}x{h}: only {ok}/9 directions agree"
        );
    }
}

#[test]
fn prop_native_conv_executor_passes_gradient_check() {
    // The whole-net finite-difference check the pipeline gate runs, on
    // the registered lenet-s entry and a second geometry, across seeds.
    use proxcomp::runtime::{native, Manifest};
    let manifest = Manifest::native();
    let lenet_s = manifest.model("lenet-s").unwrap();
    for seed in [0u64, 1, 2] {
        let (ok, total) = native::gradient_check(lenet_s, seed, 4).unwrap();
        assert!(ok >= native::FD_MIN_AGREE, "seed {seed}: {ok}/{total}");
    }
    // And the MLP family keeps passing through the same entry point.
    let (ok, _) = native::gradient_check(manifest.model("mlp-s").unwrap(), 0, 4).unwrap();
    assert!(ok >= native::FD_MIN_AGREE);
}

#[test]
fn prop_lenet_training_bit_deterministic_across_env_thread_counts() {
    // The conv twin of the MLP whole-training-loop determinism test:
    // im2col/col2im, the conv matmuls, max-pool backward and the prox
    // must all be bit-identical under PROXCOMP_THREADS=1 and =4.
    use proxcomp::config::RunConfig;
    use proxcomp::coordinator::{trainer::StepScalars, Trainer};
    use proxcomp::runtime::{Manifest, Runtime};
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = EnvThreadsGuard(std::env::var("PROXCOMP_THREADS").ok());
    let manifest = Manifest::native();
    let cfg = RunConfig {
        model: "lenet-s".into(),
        steps: 4,
        lambda: 0.5,
        lr: 2e-3,
        train_examples: 64,
        test_examples: 32,
        artifacts_dir: "native".into(),
        ..RunConfig::default()
    };
    let run = |threads: &str| {
        std::env::set_var("PROXCOMP_THREADS", threads);
        let mut rt = Runtime::native();
        let mut trainer = Trainer::new(&manifest, &cfg).unwrap();
        let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
        let mut losses = Vec::new();
        for _ in 0..cfg.steps {
            losses.push(trainer.step(&mut rt, "train_prox_adam", scalars).unwrap());
        }
        let eval = trainer.evaluate(&mut rt).unwrap();
        (losses, trainer.state.params.values.clone(), eval.loss, eval.accuracy)
    };
    let (losses1, params1, eloss1, eacc1) = run("1");
    let (losses4, params4, eloss4, eacc4) = run("4");
    assert_bits_eq(&losses1, &losses4, "per-step losses");
    for (i, (a, b)) in params1.iter().zip(&params4).enumerate() {
        assert_bits_eq(a, b, &format!("parameter leaf {i}"));
    }
    assert_eq!(eloss1.to_bits(), eloss4.to_bits(), "eval loss");
    assert_eq!(eacc1, eacc4, "eval accuracy");
}

// ---------------------------------------------------------------------------
// Quantization subsystem invariants (quant::QcsMatrix + codebooks)
// ---------------------------------------------------------------------------

#[test]
fn prop_qcs_dxct_and_spmv_bit_identical_across_thread_counts() {
    // The quantized serving kernels carry the same contract as every
    // other sparse kernel: bit-identical results for any worker count,
    // at both the batch-partitioned and the column-partitioned shapes.
    use proxcomp::quant::{QcsMatrix, QuantConfig};
    let mut rng = Rng::new(130);
    for case in 0..CASES {
        let n = 1 + rng.below(40);
        let k = 1 + rng.below(50);
        let dense = random_dense(&mut rng, n, k, 0.25);
        let q = QcsMatrix::from_dense(&dense, n, k, &QuantConfig::default());
        for b in [1usize, 3, 16] {
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let t1 = q.dxct_threads(&d, 1);
            for threads in [2usize, 4, 7] {
                let tn = q.dxct_threads(&d, threads);
                assert_bits_eq(&t1.data, &tn.data, &format!("case {case} b={b} t={threads}"));
            }
        }
        let x: Vec<f32> = rng.normal_vec(k, 1.0);
        let s1 = q.spmv_threads(&x, 1);
        for threads in [2usize, 4] {
            assert_bits_eq(&s1, &q.spmv_threads(&x, threads), &format!("spmv case {case}"));
        }
    }
}

#[test]
fn prop_qcs_kernel_matches_dequantized_csr_bit_exactly() {
    // The QCS kernel walks the identical nonzeros with the identical
    // per-element reduction as the CSR kernel of the same family (both
    // dispatch on PROXCOMP_KERNEL) — only the value load goes through
    // the codebook — so on the dequantized CSR twin the results are
    // bit-equal, not just close, in either kernel mode.
    use proxcomp::quant::{QcsMatrix, QuantConfig};
    let mut rng = Rng::new(131);
    for case in 0..CASES {
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let dense = random_dense(&mut rng, n, k, 0.3);
        let q = QcsMatrix::from_dense(&dense, n, k, &QuantConfig::default());
        let csr = q.to_csr();
        let b = 1 + rng.below(6);
        let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let got = q.dxct_threads(&d, 1);
        let want = ops::dxct_threads(&d, &csr, 1);
        assert_bits_eq(&got.data, &want.data, &format!("case {case}"));
    }
}

#[test]
fn prop_dequantize_error_bounded_by_reported_error() {
    // dequantize(quantize(W)) must stay within the error the quantizer
    // itself reported — per element (max_abs_err) and in RMS.
    use proxcomp::quant::kmeans_codebook;
    let mut rng = Rng::new(132);
    for case in 0..CASES {
        let n = 1 + rng.below(4000);
        let values: Vec<f32> = rng.normal_vec(n, 0.2);
        let k = 1 + rng.below(32);
        let (cb, codes, stats) = kmeans_codebook(&values, k, 25, case as u64);
        assert!(!cb.is_empty() && cb.len() <= k.min(256));
        let mut sq = 0.0f64;
        for (&v, &c) in values.iter().zip(&codes) {
            let e = (v - cb[c as usize]).abs();
            assert!(
                e <= stats.max_abs_err + 1e-7,
                "case {case}: element error {e} > reported {}",
                stats.max_abs_err
            );
            sq += (e as f64) * (e as f64);
        }
        let rms = (sq / values.len() as f64).sqrt();
        assert!(rms <= stats.rmse + 1e-9, "case {case}: rms {rms} > reported {}", stats.rmse);
    }
}

#[test]
fn prop_one_cluster_codebook_degrades_gracefully() {
    // k = 1 is the degenerate floor: every nonzero collapses onto one
    // centroid, yet the matrix stays structurally valid, keeps its
    // sparsity pattern, and its kernels agree with the dequantized CSR.
    use proxcomp::quant::{QcsMatrix, QuantConfig};
    let mut rng = Rng::new(133);
    for case in 0..12 {
        let n = 2 + rng.below(20);
        let k = 2 + rng.below(30);
        let dense = random_dense(&mut rng, n, k, 0.4);
        let cfg = QuantConfig { codebook_size: 1, ..QuantConfig::default() };
        let q = QcsMatrix::from_dense(&dense, n, k, &cfg);
        q.validate().unwrap();
        assert!(q.codebook().len() <= 1, "case {case}");
        let back = q.to_dense();
        for (b, d) in back.iter().zip(&dense) {
            assert_eq!(*b == 0.0, *d == 0.0, "case {case}: pattern changed");
        }
        let d = Tensor::new(vec![2, k], rng.normal_vec(2 * k, 1.0));
        let got = q.dxct_threads(&d, 1);
        let want = ops::dxct_threads(&d, &q.to_csr(), 1);
        assert_bits_eq(&got.data, &want.data, &format!("case {case}"));
    }
}

#[test]
fn prop_quantized_checkpoint_roundtrip_preserves_codebooks() {
    // save_quantized → load must reproduce codes, codebooks, and the
    // sparsity pattern bit-exactly across random sparse bundles.
    use proxcomp::quant::{quantize_bundle, QuantConfig, QuantLeaf};
    let mut rng = Rng::new(134);
    let dir = std::env::temp_dir().join("proxcomp_prop_quant");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..8 {
        let n = 8 + rng.below(24);
        let k = 8 + rng.below(48);
        let specs = vec![
            ParamSpec::new("fc1_w", "fc_w", vec![n, k], true),
            ParamSpec::new("fc1_b", "fc_b", vec![n], false),
        ];
        let values = vec![random_dense(&mut rng, n, k, 0.4), rng.normal_vec(n, 0.1)];
        let bundle = ParamBundle { specs, values };
        let cfg = QuantConfig { min_quant_nnz: 1, ..QuantConfig::default() };
        let (qm, _) = quantize_bundle(&bundle, &cfg);
        let path = dir.join(format!("case{case}.pxcp"));
        let meta = proxcomp::util::json::Json::obj();
        proxcomp::checkpoint::save_quantized(&path, &qm, &meta).unwrap();
        let ck = proxcomp::checkpoint::load(&path).unwrap();
        assert_eq!(ck.params.values, qm.to_bundle().values, "case {case}: dense view");
        let back = ck.to_quantized_model();
        for (a, b) in qm.leaves.iter().zip(&back.leaves) {
            match (a, b) {
                (QuantLeaf::Qcs(x), QuantLeaf::Qcs(y)) => assert_eq!(x, y, "case {case}"),
                (QuantLeaf::Dense(x), QuantLeaf::Dense(y)) => assert_eq!(x, y, "case {case}"),
                _ => panic!("case {case}: leaf encoding changed"),
            }
        }
    }
}
