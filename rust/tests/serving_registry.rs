//! Integration tests for multi-model fleet serving: the
//! [`proxcomp::inference::ModelRegistry`] behind the framed-TCP
//! front-end, wire-v2 `INFER_MODEL` routing, lazy loading with
//! byte-budgeted LRU eviction, and the acceptance contract of the fleet
//! redesign — mixed traffic across three model families answers
//! bit-identically to local twin engines while a model is evicted and
//! hot-reloaded mid-run, with zero dropped non-`overloaded` requests.
//!
//! Every server binds `127.0.0.1:0` (ephemeral port), so the tests run
//! concurrently without colliding.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use proxcomp::inference::{
    BatchConfig, BatchServer, Engine, EngineFactory, ErrorCode, ModelRegistry, ModelSpec,
    NetClient, NetConfig, NetServer, RegistryConfig, WeightMode,
};
use proxcomp::runtime::{Manifest, ParamBundle};
use proxcomp::sparse::prox;
use proxcomp::tensor::Tensor;
use proxcomp::util::rng::Rng;

const SEED: u64 = 21;
const PRUNE: f32 = 0.05;

/// The same deterministic synthetic engine `proxcomp serve --models`
/// builds for each id: He-init at the manifest shapes, soft-threshold
/// prune, CSR deploy. Same (model, SEED) → bit-identical weights — the
/// factory determinism hot-reload relies on.
fn synthetic_engine(model: &str) -> (Arc<Engine>, (usize, usize, usize)) {
    let manifest = Manifest::native();
    let entry = manifest.model(model).unwrap();
    let shape = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
    let mut bundle = ParamBundle::he_init(&entry.params, SEED);
    for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if s.prunable {
            prox::soft_threshold_inplace(v, PRUNE);
        }
    }
    (Arc::new(Engine::builder(model).bundle(&bundle).mode(WeightMode::Csr).build().unwrap()), shape)
}

fn factory(model: &'static str) -> EngineFactory {
    Arc::new(move || Ok(synthetic_engine(model).0))
}

/// A registry over synthetic engines; the first id is the v1 default.
fn fleet_registry(models: &[&'static str], budget: usize, max_batch: usize) -> Arc<ModelRegistry> {
    let reg = ModelRegistry::new(RegistryConfig {
        memory_budget_bytes: budget,
        default_model: Some(models[0].to_string()),
    });
    let manifest = Manifest::native();
    for m in models {
        let entry = manifest.model(m).unwrap();
        let shape = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
        reg.add_model(ModelSpec::new(
            m,
            factory(m),
            BatchConfig::new(max_batch, Duration::from_millis(1), shape),
        ))
        .unwrap();
    }
    Arc::new(reg)
}

fn ephemeral() -> NetConfig {
    NetConfig { addr: "127.0.0.1:0".to_string(), ..NetConfig::default() }
}

fn connect(server: &NetServer) -> NetClient {
    NetClient::connect(&server.local_addr().to_string(), Duration::from_secs(5)).unwrap()
}

#[test]
fn mixed_fleet_bit_exact_while_evicting_and_hot_reloading() {
    const MODELS: [&str; 3] = ["mlp-s", "lenet-s", "resnet-s"];
    const REQUESTS: usize = 40;
    let registry = fleet_registry(&MODELS, 0, 4);
    let mut server = NetServer::start_registry(Arc::clone(&registry), ephemeral()).unwrap();
    let addr = server.local_addr().to_string();
    let retries = AtomicU64::new(0);
    std::thread::scope(|scope| {
        for (mi, model) in MODELS.iter().enumerate() {
            let (twin, shape) = synthetic_engine(model);
            let addr = addr.clone();
            let retries = &retries;
            scope.spawn(move || {
                let mut client = NetClient::connect(&addr, Duration::from_secs(5)).unwrap();
                let n = shape.0 * shape.1 * shape.2;
                let mut rng = Rng::new(100 + mi as u64);
                for req in 0..REQUESTS {
                    let sample = rng.normal_vec(n, 1.0);
                    // Explicit backpressure is the only tolerated refusal;
                    // a drop, unknown-model, or engine error mid-eviction
                    // breaks the fleet contract.
                    let logits = loop {
                        match client.infer_model(model, &sample).unwrap() {
                            Ok(l) => break l,
                            Err((ErrorCode::Overloaded, _)) => {
                                retries.fetch_add(1, Ordering::Relaxed);
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            Err((code, msg)) => panic!("{model} req {req}: {code:?} {msg}"),
                        }
                    };
                    let x = Tensor::new(vec![1, shape.0, shape.1, shape.2], sample);
                    let want = twin.forward(&x).unwrap().data;
                    assert_eq!(want.len(), logits.len(), "{model} req {req}");
                    for (a, b) in want.iter().zip(&logits) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{model} req {req}: bit mismatch");
                    }
                }
            });
        }
        // Meanwhile: evict lenet-s repeatedly. Requests racing the
        // eviction must hot-reload through the factory, not drop.
        for _ in 0..6 {
            std::thread::sleep(Duration::from_millis(3));
            registry.evict("lenet-s").unwrap();
        }
    });
    let stats = server.registry().stats_json();
    let lenet = stats.get("lenet-s").unwrap();
    let count = |k: &str| lenet.get(k).unwrap().as_f64().unwrap() as u64;
    assert!(count("loads") >= 2, "lenet-s never hot-reloaded: {}", stats.to_string_compact());
    assert!(count("evictions") >= 1, "{}", stats.to_string_compact());
    // Retired incarnations keep counting: every request is accounted.
    assert_eq!(count("requests_total"), REQUESTS as u64);
    // v1 (versionless) INFER still routes to the default model.
    let (twin, shape) = synthetic_engine(MODELS[0]);
    let mut v1 = connect(&server);
    let sample = Rng::new(7).normal_vec(shape.0 * shape.1 * shape.2, 1.0);
    let logits = v1.infer(&sample).unwrap().unwrap();
    let want =
        twin.forward(&Tensor::new(vec![1, shape.0, shape.1, shape.2], sample)).unwrap().data;
    assert_eq!(want, logits);
    server.shutdown();
}

#[test]
fn memory_budget_lru_eviction_over_the_wire() {
    let bytes_mlp = synthetic_engine("mlp-s").0.model_size_bytes();
    let bytes_lenet = synthetic_engine("lenet-s").0.model_size_bytes();
    // The budget fits either model alone but never both at once.
    let budget = bytes_mlp.max(bytes_lenet);
    assert!(budget < bytes_mlp + bytes_lenet);
    let registry = fleet_registry(&["mlp-s", "lenet-s"], budget, 4);
    let mut server = NetServer::start_registry(Arc::clone(&registry), ephemeral()).unwrap();
    let mut client = connect(&server);
    let s_mlp = Rng::new(1).normal_vec(784, 1.0);
    let s_lenet = Rng::new(2).normal_vec(256, 1.0);
    assert!(registry.resident_models().is_empty(), "loads must be lazy");
    client.infer_model("mlp-s", &s_mlp).unwrap().unwrap();
    assert_eq!(registry.resident_models(), vec!["mlp-s".to_string()]);
    // Loading the second model forces the first out (LRU under budget).
    client.infer_model("lenet-s", &s_lenet).unwrap().unwrap();
    assert_eq!(registry.resident_models(), vec!["lenet-s".to_string()]);
    assert!(registry.resident_bytes() <= budget);
    // Swapping back hot-reloads deterministically: repeated answers are
    // bit-identical to each other and to a local twin forward.
    let a = client.infer_model("mlp-s", &s_mlp).unwrap().unwrap();
    let b = client.infer_model("mlp-s", &s_mlp).unwrap().unwrap();
    assert_eq!(a, b);
    let twin = synthetic_engine("mlp-s").0;
    assert_eq!(a, twin.forward(&Tensor::new(vec![1, 1, 28, 28], s_mlp)).unwrap().data);
    server.shutdown();
}

#[test]
fn unknown_model_is_recoverable_on_the_same_connection() {
    let registry = fleet_registry(&["mlp-s"], 0, 4);
    let mut server = NetServer::start_registry(Arc::clone(&registry), ephemeral()).unwrap();
    let mut client = connect(&server);
    let sample = Rng::new(3).normal_vec(784, 1.0);
    let (code, msg) = client.infer_model("ghost", &sample).unwrap().unwrap_err();
    assert_eq!(code, ErrorCode::UnknownModel, "{msg}");
    assert!(msg.contains("ghost"), "the error should name the model: {msg}");
    // The connection survives a recoverable error.
    assert_eq!(client.infer_model("mlp-s", &sample).unwrap().unwrap().len(), 10);
    assert_eq!(server.net_counters().unknown_model, 1);
    server.shutdown();
}

#[test]
fn resnet_s_serves_coalesced_batches_bit_exactly() {
    let (engine, shape) = synthetic_engine("resnet-s");
    // Inference-mode BN folds the running statistics into an elementwise
    // transform, so nothing pins the pool to single-sample batches.
    assert!(!engine.uses_batch_stats(), "resnet-s must deploy inference-mode BN");
    let server = BatchServer::start(
        Arc::clone(&engine),
        BatchConfig::new(8, Duration::from_millis(50), shape),
    );
    assert!(server.config().max_batch > 1, "the batch-statistics pin must not trigger");
    let mut rng = Rng::new(4);
    let n = shape.0 * shape.1 * shape.2;
    let pending: Vec<_> = (0..8)
        .map(|_| {
            let sample = rng.normal_vec(n, 1.0);
            let p = server.submit(&sample).unwrap();
            (sample, p)
        })
        .collect();
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        let x = Tensor::new(vec![1, shape.0, shape.1, shape.2], sample);
        let want = engine.forward(&x).unwrap().data;
        assert_eq!(want.len(), got.len());
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits(), "coalesced resnet logits diverge");
        }
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 8);
    assert!(stats.max_batch > 1, "requests were never coalesced into a real batch");
    server.shutdown();
}
