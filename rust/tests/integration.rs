//! Integration tests over the real AOT artifacts + PJRT runtime.
//!
//! These require `make artifacts` to have run (the manifest test fails
//! loudly with instructions otherwise). One shared Runtime per process
//! keeps compilation costs amortized; tests use the small `mlp` model so
//! the whole file stays fast.
//!
//! The whole file is gated on the `pjrt` feature: the default offline
//! build has no PJRT runtime (see `proxcomp::xla_compat`) and no compiled
//! artifacts, so these tests only exist when the real stack is present
//! (`cargo test --features pjrt`).

#![cfg(feature = "pjrt")]

use std::sync::Mutex;

use proxcomp::compress::{self, debias};
use proxcomp::config::{Method, Optimizer, RunConfig};
use proxcomp::coordinator::{trainer::StepScalars, Trainer};
use proxcomp::inference::Engine;
use proxcomp::runtime::{Manifest, Runtime};
use proxcomp::tensor::Tensor;
use proxcomp::util::json::Json;

/// Serialize runtime-using tests (one PJRT client; avoids oversubscribing
/// the CPU when `cargo test` runs threads in parallel). Poison is ignored:
/// one failing test must not cascade into every later one.
static RT_LOCK: Mutex<()> = Mutex::new(());

fn rt_lock() -> std::sync::MutexGuard<'static, ()> {
    RT_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn manifest() -> Manifest {
    Manifest::load("artifacts").expect("run `make artifacts` before `cargo test`")
}

fn small_cfg(model: &str) -> RunConfig {
    RunConfig {
        model: model.into(),
        steps: 25,
        lambda: 0.5,
        lr: 1e-3,
        train_examples: 512,
        test_examples: 256,
        ..RunConfig::default()
    }
}

#[test]
fn manifest_covers_all_models_and_steps() {
    let m = manifest();
    for name in ["mlp", "lenet", "alexnet_s", "vgg_s", "resnet_s"] {
        let entry = m.model(name).unwrap();
        for step in [
            "train_prox_adam",
            "train_prox_rmsprop",
            "train_prox_sgd",
            "train_masked",
            "train_mm",
            "eval",
            "infer",
        ] {
            let a = entry.artifact(step).unwrap();
            assert!(a.file.exists(), "{name}/{step} missing");
            assert!(!a.inputs.is_empty() && !a.outputs.is_empty());
        }
    }
}

#[test]
fn training_decreases_loss_and_creates_exact_zeros() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
    let first = trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    let mut last = first;
    for _ in 0..24 {
        last = trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // The prox writes exact zeros during training (Section 2.2).
    assert!(
        trainer.state.params.zero_weights() > 100,
        "prox produced no zeros"
    );
    // Timestep advanced.
    assert_eq!(trainer.state.t, 25.0);
}

#[test]
fn rmsprop_and_sgd_artifacts_run() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    for step in ["train_prox_rmsprop", "train_prox_sgd"] {
        let cfg = small_cfg("mlp");
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: 0.5, lr: 1e-3, mu: 0.0 };
        let loss = trainer.step(&mut rt, step, scalars).unwrap();
        assert!(loss.is_finite(), "{step} produced {loss}");
    }
}

#[test]
fn lambda_zero_never_zeroes_weights() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 0.0, lr: 1e-3, mu: 0.0 };
    for _ in 0..5 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    assert_eq!(trainer.state.params.zero_weights(), 0);
}

#[test]
fn masked_step_never_resurrects_zeros() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    // Sparsify hard, then retrain.
    let scalars = StepScalars { lambda: 5.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let zeros_before = trainer.state.params.zero_weights();
    assert!(zeros_before > 1000);
    debias::retrain(&mut rt, &mut trainer, 10, 1e-4).unwrap();
    assert!(
        trainer.state.params.zero_weights() >= zeros_before,
        "retraining resurrected zeros"
    );
}

#[test]
fn higher_lambda_compresses_more() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut rates = Vec::new();
    for lam in [0.2f32, 1.0, 4.0] {
        let cfg = small_cfg("mlp");
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: lam, lr: 1e-3, mu: 0.0 };
        for _ in 0..15 {
            trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
        }
        rates.push(trainer.state.params.compression_rate());
    }
    assert!(rates[0] < rates[1] && rates[1] < rates[2], "{rates:?}");
}

#[test]
fn seeds_reproduce_and_differ() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let run = |rt: &mut Runtime, seed: u64| {
        let mut cfg = small_cfg("mlp");
        cfg.seed = seed;
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: 0.5, lr: 1e-3, mu: 0.0 };
        let mut loss = 0.0;
        for _ in 0..5 {
            loss = trainer.step(rt, "train_prox_adam", scalars).unwrap();
        }
        loss
    };
    let a = run(&mut rt, 7);
    let b = run(&mut rt, 7);
    let c = run(&mut rt, 8);
    assert_eq!(a, b, "same seed must reproduce bit-exactly");
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn evaluate_returns_sane_metrics() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let eval = trainer.evaluate(&mut rt).unwrap();
    assert_eq!(eval.n, cfg.test_examples);
    assert!(eval.accuracy >= 0.0 && eval.accuracy <= 1.0);
    // Untrained net ≈ uniform predictions.
    assert!(eval.loss > 1.5 && eval.loss < 3.5, "loss {}", eval.loss);
    // Training improves accuracy.
    let scalars = StepScalars { lambda: 0.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..25 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let eval2 = trainer.evaluate(&mut rt).unwrap();
    assert!(eval2.accuracy > eval.accuracy + 0.1, "{} -> {}", eval.accuracy, eval2.accuracy);
}

#[test]
fn spc_controller_end_to_end() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg("mlp");
    cfg.steps = 40;
    cfg.lambda = 1.0;
    cfg.retrain_steps = 10;
    let r = compress::spc::run(&mut rt, &m, &cfg).unwrap();
    assert_eq!(r.method, "SpC(Retrain)");
    assert!(r.compression_rate > 0.05);
    assert!(r.accuracy > 0.3);
    assert_eq!(r.nnz + trainer_zero(&r), r.total_weights);
}

fn trainer_zero(r: &proxcomp::metrics::RunResult) -> usize {
    r.total_weights - r.nnz
}

#[test]
fn pru_controller_hits_target_rate() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg("mlp");
    cfg.method = Method::Pru;
    cfg.pru_target_rate = 0.8;
    cfg.retrain_steps = 5;
    let r = compress::pruning::run(&mut rt, &m, &cfg).unwrap();
    assert!((r.compression_rate - 0.8).abs() < 0.02, "rate {}", r.compression_rate);
}

#[test]
fn mm_controller_produces_sparse_model() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg("mlp");
    cfg.method = Method::MM;
    cfg.steps = 60;
    cfg.pru_target_rate = 0.8; // ℓ0-constraint C-step target (κ)
    cfg.mm_mu0 = 0.1;
    cfg.mm_mu_growth = 1.5;
    cfg.mm_compress_every = 6;
    cfg.lr = 0.02;
    let r = compress::mm::run(&mut rt, &m, &cfg).unwrap();
    // The ℓ0 C-step pins the rate exactly.
    assert!((r.compression_rate - 0.8).abs() < 0.02, "MM rate {}", r.compression_rate);
    assert!(r.accuracy > 0.2, "MM accuracy collapsed: {}", r.accuracy);
}

#[test]
fn optimizer_selection_routes_to_artifact() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg("mlp");
    cfg.optimizer = Optimizer::ProxRmsprop;
    cfg.steps = 10;
    let r = compress::spc::run(&mut rt, &m, &cfg).unwrap();
    assert!(r.accuracy > 0.0);
}

#[test]
fn engine_matches_xla_logits_dense_and_sparse() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    for model in ["mlp", "lenet", "alexnet_s", "vgg_s", "resnet_s"] {
        let mut cfg = small_cfg(model);
        cfg.train_examples = 256;
        cfg.test_examples = 160;
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        // Train a bit with prox so sparse != trivial. (resnet_s is skipped
        // for training here — batch-stats BN makes its logits depend on
        // batch composition, which the parity check covers anyway.)
        let steps = if model == "resnet_s" { 0 } else { 4 };
        let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
        for _ in 0..steps {
            trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
        }
        let artifact = trainer.entry.artifact("infer").unwrap().clone();
        let batch = artifact.batch;
        let mut xs = Vec::new();
        for i in 0..batch {
            xs.extend_from_slice(trainer.test_data.image(i % trainer.test_data.n));
        }
        let mut inputs = trainer.state.params.to_host_values();
        let (c, h, w) = (
            trainer.entry.input_shape[0],
            trainer.entry.input_shape[1],
            trainer.entry.input_shape[2],
        );
        inputs.push(proxcomp::runtime::HostValue::F32 {
            shape: vec![batch, c, h, w],
            data: xs.clone(),
        });
        let xla = rt.execute(&artifact.file, &inputs).unwrap()[0]
            .as_f32()
            .unwrap()
            .to_vec();
        let x = Tensor::new(vec![batch, c, h, w], xs);
        // Conv stacks accumulate more rounding (im2col vs XLA's fused
        // convolutions; BN rsqrt), so their tolerance is looser.
        let tol = if model == "mlp" { 5e-3 } else { 2e-2 };
        for mode in [proxcomp::inference::WeightMode::Dense, proxcomp::inference::WeightMode::Csr] {
            let engine =
                Engine::builder(model).bundle(&trainer.state.params).mode(mode).build().unwrap();
            let logits = engine.forward(&x).unwrap();
            let max_diff = xla
                .iter()
                .zip(&logits.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_diff < tol,
                "{model} mode={mode:?}: engine/XLA max diff {max_diff}"
            );
        }
    }
}

#[test]
fn spc_smoke_loss_decreases_and_formats_deploy() {
    // Satellite smoke test: a handful of SpC steps on the tiny mlp
    // manifest must drive the loss down, and the trained model must
    // deploy through the dispatch engine with a non-empty (and fully
    // compressed) per-layer format report.
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let mut cfg = small_cfg("mlp");
    cfg.steps = 30;
    cfg.retrain_steps = 0;
    let r = compress::spc::run(&mut rt, &m, &cfg).unwrap();
    let first = r.history.records.first().unwrap().loss;
    let last = r.history.records.last().unwrap().loss;
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(r.compression_rate > 0.0, "SpC produced no zeros");

    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let auto = Engine::builder("mlp")
        .bundle(&trainer.state.params)
        .mode(proxcomp::inference::WeightMode::Auto)
        .build()
        .unwrap();
    let formats = auto.layer_formats();
    assert!(!formats.is_empty(), "layer_formats() report is empty");
    assert!(formats.iter().all(|(_, f)| *f != "dense"), "{formats:?}");
}

#[test]
fn batch_server_serves_trained_model() {
    // The serving front-end over a genuinely trained engine: per-request
    // logits must match the engine's own batched answers.
    use proxcomp::inference::{BatchConfig, BatchServer};
    use std::sync::Arc;
    use std::time::Duration;
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let engine = Arc::new(
        Engine::builder("mlp")
            .bundle(&trainer.state.params)
            .mode(proxcomp::inference::WeightMode::Csr)
            .build()
            .unwrap(),
    );
    let server = BatchServer::start(
        Arc::clone(&engine),
        BatchConfig::new(8, Duration::from_millis(20), (1, 28, 28)),
    );
    let pending: Vec<_> = (0..12)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            (sample.clone(), server.submit(&sample).unwrap())
        })
        .collect();
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(got, engine.forward(&x).unwrap().data);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 12);
    assert!(stats.batches >= 2);
}

#[test]
fn checkpoint_roundtrip_through_trained_model() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 2.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let dir = std::env::temp_dir().join("proxcomp_integration");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.pxcp");
    let mut meta = Json::obj();
    meta.set("model", Json::from("mlp"));
    proxcomp::checkpoint::save(&path, &trainer.state.params, &meta).unwrap();
    let ck = proxcomp::checkpoint::load(&path).unwrap();
    assert_eq!(ck.params.values, trainer.state.params.values);
    // Engine accepts the loaded bundle.
    let engine = Engine::builder("mlp")
        .bundle(&ck.params)
        .mode(proxcomp::inference::WeightMode::Csr)
        .build()
        .unwrap();
    assert!(engine.model_size_bytes() > 0);
}

#[test]
fn eval_artifact_agrees_with_infer_path() {
    let _g = rt_lock();
    let m = manifest();
    let mut rt = Runtime::cpu().unwrap();
    let cfg = small_cfg("mlp");
    let trainer = Trainer::new(&m, &cfg).unwrap();
    let artifact = trainer.entry.artifact("eval").unwrap().clone();
    let batch = artifact.batch;
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..batch {
        xs.extend_from_slice(trainer.test_data.image(i % trainer.test_data.n));
        ys.push(trainer.test_data.labels[i % trainer.test_data.n]);
    }
    let mut inputs = trainer.state.params.to_host_values();
    inputs.push(proxcomp::runtime::HostValue::F32 { shape: vec![batch, 1, 28, 28], data: xs });
    inputs.push(proxcomp::runtime::HostValue::I32 { shape: vec![batch], data: ys });
    let out = rt.execute(&artifact.file, &inputs).unwrap();
    let loss = out[0].scalar().unwrap();
    let correct = out[1].scalar().unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=batch as f32).contains(&correct));
}
