//! Integration tests for the METRICS export surface (wire opcode 6):
//! the versioned JSON snapshot must agree with what the load generator
//! observed from the outside (per-model `requests_total` == completed +
//! engine-error + deadline-exceeded admissions), the per-layer profiles
//! it carries must match the served engine's weight storage (nnz /
//! density straight from the pruned checkpoint), and the Prometheus
//! rendering must expose the same series.
//!
//! Every server binds `127.0.0.1:0` (ephemeral port), so the tests run
//! concurrently without colliding.

use std::sync::Arc;
use std::time::Duration;

use proxcomp::inference::loadgen::{self, LoadConfig, LoadTarget};
use proxcomp::inference::{
    BatchConfig, Engine, EngineFactory, ErrorCode, ModelRegistry, ModelSpec, NetClient,
    NetConfig, NetServer, RegistryConfig, WeightMode,
};
use proxcomp::runtime::{Manifest, ParamBundle};
use proxcomp::sparse::prox;
use proxcomp::util::json::{self, Json};
use proxcomp::util::rng::Rng;

const SEED: u64 = 33;
const PRUNE: f32 = 0.05;

/// Deterministic synthetic engine (He-init at the manifest shapes,
/// soft-threshold prune, CSR deploy) plus the pruned bundle it was
/// built from — the ground truth for the profile-sparsity check.
fn synthetic_engine(model: &str) -> (Arc<Engine>, ParamBundle, (usize, usize, usize)) {
    let manifest = Manifest::native();
    let entry = manifest.model(model).unwrap();
    let shape = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
    let mut bundle = ParamBundle::he_init(&entry.params, SEED);
    for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if s.prunable {
            prox::soft_threshold_inplace(v, PRUNE);
        }
    }
    let engine =
        Arc::new(Engine::builder(model).bundle(&bundle).mode(WeightMode::Csr).build().unwrap());
    (engine, bundle, shape)
}

fn factory(model: &'static str) -> EngineFactory {
    Arc::new(move || Ok(synthetic_engine(model).0))
}

fn fleet_registry(models: &[&'static str], max_batch: usize) -> Arc<ModelRegistry> {
    let reg = ModelRegistry::new(RegistryConfig {
        memory_budget_bytes: 0,
        default_model: Some(models[0].to_string()),
    });
    let manifest = Manifest::native();
    for m in models {
        let entry = manifest.model(m).unwrap();
        let shape = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
        reg.add_model(ModelSpec::new(
            m,
            factory(m),
            BatchConfig::new(max_batch, Duration::from_millis(1), shape),
        ))
        .unwrap();
    }
    Arc::new(reg)
}

fn ephemeral() -> NetConfig {
    NetConfig { addr: "127.0.0.1:0".to_string(), ..NetConfig::default() }
}

fn connect(server: &NetServer) -> NetClient {
    NetClient::connect(&server.local_addr().to_string(), Duration::from_secs(5)).unwrap()
}

fn get_u64(j: &Json, key: &str) -> u64 {
    j.get(key).and_then(|v| v.as_f64()).unwrap_or_else(|| panic!("missing {key}")) as u64
}

/// Drive a two-model fleet with the load generator, then scrape METRICS
/// and check the server's books against the client's: for every model,
/// `requests_total` (admissions into the batch pool, across evictions)
/// must equal the loadgen-observed completions plus the two error codes
/// that are only raised *after* admission.
#[test]
fn metrics_counters_match_loadgen_report() {
    const MODELS: [&str; 2] = ["mlp-s", "lenet-s"];
    let registry = fleet_registry(&MODELS, 8);
    let mut server = NetServer::start_registry(Arc::clone(&registry), ephemeral()).unwrap();
    let targets: Vec<LoadTarget> = MODELS
        .iter()
        .map(|m| {
            let (twin, _, shape) = synthetic_engine(m);
            LoadTarget::new(Some(m), shape, Some(twin))
        })
        .collect();
    let cfg = LoadConfig {
        addr: server.local_addr().to_string(),
        clients: 6,
        duration: Duration::from_millis(400),
        targets,
        seed: 11,
        connect_timeout: Duration::from_secs(5),
        retry_budget: 8,
        retry_base: Duration::from_micros(200),
        fetch_server_stats: false,
    };
    let report = loadgen::run(&cfg).unwrap();
    assert!(report.ok > 0, "closed loop completed no requests");
    assert_eq!(report.mismatches, 0, "wire responses diverged from local forward");

    let mut client = connect(&server);
    let metrics = json::parse(&client.metrics_json().unwrap()).unwrap();
    assert_eq!(metrics.get("version").and_then(|v| v.as_f64()), Some(1.0));
    let models = metrics.get("models").expect("models table");
    let mut total_admitted = 0u64;
    for (mi, model) in MODELS.iter().enumerate() {
        let row = models.get(model).unwrap_or_else(|| panic!("no models row for {model}"));
        let admitted = get_u64(row, "requests_total");
        total_admitted += admitted;
        let m = &report.per_model[mi];
        assert_eq!(m.model.as_deref(), Some(*model));
        let expected = m.ok
            + m.error_count(ErrorCode::EngineError)
            + m.error_count(ErrorCode::DeadlineExceeded);
        assert_eq!(
            admitted, expected,
            "{model}: server admitted {admitted}, loadgen observed {expected}"
        );
    }
    // The fleet roll-up counts the same admissions.
    let serving = metrics.get("serving").expect("serving roll-up");
    assert_eq!(get_u64(serving, "requests"), total_admitted);
    // Satellite: merged-histogram fleet percentiles are ordered and real.
    let p50 = serving.get("p50_latency_us").and_then(|v| v.as_f64()).unwrap();
    let p99 = serving.get("p99_latency_us").and_then(|v| v.as_f64()).unwrap();
    let max = serving.get("max_latency_us").and_then(|v| v.as_f64()).unwrap();
    assert!(p50 > 0.0 && p50 <= p99 && p99 <= max, "p50={p50} p99={p99} max={max}");
    // The loadgen report JSON carries the new per-model breakdowns.
    let rj = report.to_json();
    assert!(rj.get("backoff_us").is_some());
    let first = rj.get("per_model").and_then(|v| v.as_arr()).unwrap().first().unwrap();
    assert!(first.get("errors").and_then(|e| e.get(ErrorCode::Overloaded.name())).is_some());
    assert!(first.get("backoff_us").is_some());

    // The Prometheus rendering exposes the same series.
    let text = client.metrics_prometheus().unwrap();
    assert!(text.contains("proxcomp_fleet_requests_total"), "{text}");
    for model in MODELS {
        assert!(
            text.contains(&format!("proxcomp_model_requests_total{{model=\"{model}\"}}")),
            "no per-model series for {model}:\n{text}"
        );
    }
    assert!(text.contains("proxcomp_layer_nnz{"), "no per-layer series:\n{text}");
    server.shutdown();
}

/// The per-layer profiles in the METRICS snapshot must mirror the served
/// engine's storage exactly — and the weight rows' nnz must add up to
/// the nonzeros of the pruned checkpoint bundle the engine was built
/// from (profiles reflect checkpoint sparsity, not a re-measurement).
#[test]
fn metrics_profiles_match_checkpoint_sparsity() {
    let (engine, bundle, shape) = synthetic_engine("mlp-s");
    let batch = BatchConfig::new(4, Duration::from_millis(1), shape);
    let mut server = NetServer::start(Arc::clone(&engine), batch, ephemeral()).unwrap();
    let mut client = connect(&server);
    let n = shape.0 * shape.1 * shape.2;
    let mut rng = Rng::new(5);
    for _ in 0..4 {
        client.infer(&rng.normal_vec(n, 1.0)).unwrap().unwrap();
    }
    let metrics = json::parse(&client.metrics_json().unwrap()).unwrap();
    let rows = metrics
        .get("profiles")
        .and_then(|p| p.get("mlp-s"))
        .and_then(|p| p.as_arr())
        .expect("profiles.mlp-s");
    let local = engine.profile();
    assert_eq!(rows.len(), local.len(), "wire profile dropped layers");
    let mut wire_nnz = 0u64;
    for (row, want) in rows.iter().zip(&local) {
        assert_eq!(row.get("layer").and_then(|v| v.as_str()), Some(want.name.as_str()));
        assert_eq!(row.get("format").and_then(|v| v.as_str()), Some(want.format.as_str()));
        assert_eq!(get_u64(row, "rows"), want.rows as u64);
        assert_eq!(get_u64(row, "cols"), want.cols as u64);
        assert_eq!(get_u64(row, "nnz"), want.nnz as u64);
        let density = row.get("density").and_then(|v| v.as_f64()).unwrap();
        assert!((density - want.density).abs() < 1e-9);
        if want.rows * want.cols > 0 {
            wire_nnz += want.nnz as u64;
            assert!(
                (density - want.nnz as f64 / (want.rows * want.cols) as f64).abs() < 1e-9,
                "{}: density {} inconsistent with nnz {}",
                want.name,
                density,
                want.nnz
            );
            assert!(density < 1.0, "{}: pruned layer reported dense", want.name);
        }
        // Traffic flowed, so weight layers must show calls and timing.
        if want.format != "op" {
            assert!(get_u64(row, "calls") > 0, "{}: no forward calls recorded", want.name);
            assert!(row.get("mean_us").and_then(|v| v.as_f64()).unwrap() >= 0.0);
        }
    }
    // Checkpoint ground truth: the engine stores exactly the pruned
    // bundle's surviving weights.
    let checkpoint_nnz: u64 = bundle
        .specs
        .iter()
        .zip(&bundle.values)
        .filter(|(s, _)| s.prunable)
        .map(|(_, v)| v.iter().filter(|x| **x != 0.0).count() as u64)
        .sum();
    assert_eq!(wire_nnz, checkpoint_nnz, "profile nnz diverged from checkpoint sparsity");
    server.shutdown();
}
