//! Vendored minimal stand-in for the `anyhow` crate.
//!
//! The offline build environment has no crates.io access, so this local
//! path dependency provides the subset of the anyhow API the workspace
//! uses: [`Error`], [`Result`], and the `anyhow!` / `bail!` / `ensure!`
//! macros, with `?`-conversion from any `std::error::Error`. It is a
//! drop-in for the real crate at this API surface; swap the path
//! dependency for the registry crate when a registry is available.

use std::fmt;

/// Boxed error with an eagerly rendered message and an optional source
/// chain. Like the real `anyhow::Error`, this type deliberately does NOT
/// implement `std::error::Error` — that keeps the blanket
/// `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// The rendered top-level message.
    pub fn to_message(&self) -> &str {
        &self.msg
    }

    /// Iterate the source chain (top-level cause first).
    pub fn chain(&self) -> Chain<'_> {
        Chain { next: self.source.as_deref().map(|s| s as &(dyn std::error::Error + 'static)) }
    }
}

/// Iterator over an [`Error`]'s source chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn std::error::Error + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn std::error::Error + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let cur = self.next?;
        self.next = cur.source();
        Some(cur)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        // `{:#}` renders the full cause chain, as real anyhow does.
        if f.alternate() {
            for cause in self.chain() {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        for cause in self.chain() {
            write!(f, "\n\nCaused by:\n    {cause}")?;
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string (with inline captures) or
/// any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::core::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::core::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails(flag: bool) -> Result<usize> {
        ensure!(flag, "flag was {flag}");
        Ok(1)
    }

    fn bails() -> Result<()> {
        bail!("bailed with {}", 42)
    }

    #[test]
    fn message_and_formatting() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 7;
        let e = anyhow!("captured {x} and {}", "positional");
        assert_eq!(e.to_string(), "captured 7 and positional");
    }

    #[test]
    fn ensure_and_bail() {
        assert_eq!(fails(true).unwrap(), 1);
        assert_eq!(fails(false).unwrap_err().to_string(), "flag was false");
        assert_eq!(bails().unwrap_err().to_string(), "bailed with 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<i32> {
            Ok(s.parse::<i32>()?)
        }
        assert_eq!(parse("12").unwrap(), 12);
        let err = parse("nope").unwrap_err();
        assert!(!err.to_string().is_empty());
        // Source chain is preserved and rendered by `{:#}`.
        assert_eq!(err.chain().count(), 1);
        let rendered = format!("{err:#}");
        assert!(rendered.starts_with(err.to_message()));
    }

    #[test]
    fn identity_question_mark() {
        fn inner() -> Result<()> {
            bail!("inner")
        }
        fn outer() -> Result<()> {
            inner()?;
            Ok(())
        }
        assert_eq!(outer().unwrap_err().to_string(), "inner");
    }
}
