#!/usr/bin/env python3
"""Regenerate the committed fuzz corpora under fuzz/corpus/.

Two kinds of files:
  valid_*  — well-formed seeds that let the fuzzer start from deep
             program states instead of rediscovering the format.
  repro_*  — minimized reproducers for decode bugs found by fuzzing /
             adversarial review. Each is pinned by a named unit test
             (see rust/src/checkpoint/mod.rs and rust/tests/fuzz_smoke.rs)
             and MUST decode to Err on fixed code; on pre-fix code each
             one aborted, panicked, or silently mis-loaded.

Layout notes (must stay in sync with rust/src/checkpoint/mod.rs):
  file   = "PXCP" | u32 version | u64 header_len | header JSON | leaves
  dense  = tag 0 | u64 n | f32[n]
  csr    = tag 1 | u64 rows, cols, nnz | u32 ptr[rows+1] | u32 idx[nnz] | f32[nnz]
  qcs    = tag 2 | u64 rows, cols, nnz | u16 k | u8 code_bits | u8 index_bytes
           | f32 codebook[k] | u32 ptr[rows+1] | idx[nnz] | packed codes
The checkpoint_v2 target prepends the v2 envelope for a [2,3] spec
itself, so its corpus files are leaf *bodies* only.
"""

import os
import struct

HERE = os.path.dirname(os.path.abspath(__file__))


def u8(*vals):
    return struct.pack("<" + "B" * len(vals), *vals)


def u16(*vals):
    return struct.pack("<" + "H" * len(vals), *vals)


def u32(*vals):
    return struct.pack("<" + "I" * len(vals), *vals)


def u64(*vals):
    return struct.pack("<" + "Q" * len(vals), *vals)


def f32(*vals):
    return struct.pack("<" + "f" * len(vals), *vals)


def header(shape, version=1):
    spec = (
        '{"meta":{},"specs":[{"name":"fc1_w","kind":"fc_w",'
        f'"shape":{shape},"prunable":true,"layer":"fc1"}}]}}'
    ).encode()
    return b"PXCP" + u32(version) + u64(len(spec)) + spec


def write(target, name, data):
    d = os.path.join(HERE, "corpus", target)
    os.makedirs(d, exist_ok=True)
    with open(os.path.join(d, name), "wb") as f:
        f.write(data)
    print(f"{target}/{name}: {len(data)} bytes")


# ---- checkpoint_v1: whole files ------------------------------------------

write("checkpoint_v1", "valid_dense_v1.pxcp",
      header("[2,3]") + u8(0) + u64(6) + f32(1.0, -2.0, 0.0, 0.5, 0.0, 3.0))

write("checkpoint_v1", "valid_csr_v1.pxcp",
      header("[2,3]") + u8(1) + u64(2, 3, 2) + u32(0, 1, 2) + u32(0, 2)
      + f32(1.5, -0.5))

write("checkpoint_v1", "valid_qcs_v2.pxcp",
      header("[2,3]", version=2) + u8(2) + u64(2, 3, 2) + u16(2) + u8(4, 2)
      + f32(0.5, -1.0) + u32(0, 1, 2) + u16(0, 2) + u8(0x10))

# Bug: `nnz as u32` truncated nnz=2^32 to 0, so the ptr consistency
# check passed against a zeroed pointer array and the decoder went on
# to allocate nnz (2^32) column indices — a 16 GiB allocation from a
# ~150-byte file. Fixed: u32::try_from(nnz) rejects before any read.
write("checkpoint_v1", "repro_nnz_u32_truncation.pxcp",
      header("[4294967296,1]") + u8(1) + u64(2**32, 1, 2**32))

# Bug: a sparse leaf's dense expansion (`to_dense`) was unbounded — a
# tiny file declaring a 4 × 2^60 CSR leaf with nnz=0 passed every
# byte-level bound, then aborted allocating the dense buffer. Fixed:
# MAX_DECODE_NUMEL caps the expansion at 2^28 elements.
write("checkpoint_v1", "repro_sparse_expansion_oom.pxcp",
      header("[4,1152921504606846976]") + u8(1)
      + u64(4, 2**60, 0) + u32(0, 0, 0, 0, 0))

# Bug: matrix_view returned (0,0) for rank-1 specs, and the geometry
# check multiplied through it — a CSR leaf attached to a 1-D spec was
# silently accepted with fabricated 2×3 geometry. Fixed: sparse leaves
# on specs with no 2-D view are rejected explicitly.
write("checkpoint_v1", "repro_sparse_on_1d_spec.pxcp",
      header("[6]") + u8(1) + u64(2, 3, 0) + u32(0, 0, 0))

write("checkpoint_v1", "bad_magic.pxcp", b"NOPE" + u32(1) + u64(0))
write("checkpoint_v1", "bad_version.pxcp", b"PXCP" + u32(99) + u64(0))
write("checkpoint_v1", "huge_header_len.pxcp",
      b"PXCP" + u32(1) + u64(2**63))
write("checkpoint_v1", "deep_json_header.pxcp",
      b"PXCP" + u32(1) + u64(400) + b"[" * 200 + b"]" * 200)

# ---- checkpoint_v2: leaf bodies (envelope added by the target) -----------

write("checkpoint_v2", "valid_dense_body.bin",
      u8(0) + u64(6) + f32(0.0, 1.0, 2.0, 3.0, 4.0, 5.0))
write("checkpoint_v2", "valid_csr_body.bin",
      u8(1) + u64(2, 3, 2) + u32(0, 1, 2) + u32(0, 2) + f32(1.5, -0.5))
write("checkpoint_v2", "valid_qcs_body.bin",
      u8(2) + u64(2, 3, 2) + u16(2) + u8(4, 2) + f32(0.5, -1.0)
      + u32(0, 1, 2) + u16(0, 2) + u8(0x10))

# Bug: the rows×cols geometry check used an unchecked multiply, so in
# release builds rows=2^63+3, cols=2 wrapped to exactly 6 (the spec's
# numel) and the decoder proceeded to allocate rows+1 row pointers —
# a capacity-overflow panic. Fixed: cursor::checked_mul + exact match
# against the spec's matrix view.
write("checkpoint_v2", "repro_dim_product_wrap.bin",
      u8(1) + u64(2**63 + 3, 2, 0))

# Truncation right before the row-pointer array: must be a bounded
# "truncated checkpoint" error, never an allocation of the declared size.
write("checkpoint_v2", "repro_truncated_ptr.bin", u8(1) + u64(2, 3, 2))

# ---- wire_frame: length-prefixed frames ----------------------------------

write("wire_frame", "valid_ping.bin", u32(1) + u8(4))
write("wire_frame", "valid_infer_model.bin",
      u32(1 + 1 + 2 + 4) + u8(5) + u8(2) + b"ok" + f32(0.5))
write("wire_frame", "zero_len.bin", u32(0))
write("wire_frame", "oversized_1gib.bin", u32(2**30))
write("wire_frame", "truncated_payload.bin", u32(8) + u8(1, 2, 3))

# ---- infer_model_body: id_len | id | sample ------------------------------

write("infer_model_body", "valid_body.bin", u8(7) + b"lenet-s" + f32(1.0, -2.5))
write("infer_model_body", "zero_id.bin", u8(0))
write("infer_model_body", "id_overrun.bin", u8(5) + b"ab")
write("infer_model_body", "bad_utf8.bin", u8(2, 0xFF, 0xFE))
write("infer_model_body", "max_id.bin", u8(255) + b"m" * 255 + f32(0.5))
