//! INFER_MODEL body parse: `id_len:u8 | id utf-8 | sample f32 LE`.
//! The id length must be bounded by the remaining body before the id
//! slice is taken, and non-UTF-8 ids must be a parse error, not a
//! panic in a later `str` consumer.
#![no_main]

use libfuzzer_sys::fuzz_target;
use proxcomp::inference::net::parse_infer_model_body;

fuzz_target!(|data: &[u8]| {
    if let Ok((id, sample)) = parse_infer_model_body(data) {
        // Parsed output must uphold the layout invariants.
        assert!(!id.is_empty() && id.len() <= u8::MAX as usize);
        assert_eq!(1 + id.len() + sample.len(), data.len());
    }
});
