//! Whole-file checkpoint decode: the fuzzer owns every byte, from the
//! magic onward. Exercises magic/version/header-length validation, the
//! JSON header parser (including its recursion-depth cap), and the v1
//! dense/CSR leaf decoders. Any input must produce `Ok` or `Err` —
//! never a panic, abort, or unbounded allocation.
#![no_main]

use libfuzzer_sys::fuzz_target;

fuzz_target!(|data: &[u8]| {
    let _ = proxcomp::checkpoint::decode(data);
});
