//! Leaf-body checkpoint decode: wrap the fuzzer's bytes in a valid
//! v2 envelope (magic | version | header for one prunable 2×3 leaf)
//! so every execution reaches the per-leaf tag dispatch — dense,
//! CSR, and the v2-only quantized-CSR (tag 2) path with its codebook
//! and packed 4-bit codes. The whole-file target rarely gets past the
//! header; this one starts there.
#![no_main]

use libfuzzer_sys::fuzz_target;

const HEADER: &str = r#"{"meta":{},"specs":[{"name":"fc1_w","kind":"fc_w","shape":[2,3],"prunable":true,"layer":"fc1"}]}"#;

fuzz_target!(|data: &[u8]| {
    let mut bytes = Vec::with_capacity(16 + HEADER.len() + data.len());
    bytes.extend_from_slice(b"PXCP");
    bytes.extend_from_slice(&2u32.to_le_bytes());
    bytes.extend_from_slice(&(HEADER.len() as u64).to_le_bytes());
    bytes.extend_from_slice(HEADER.as_bytes());
    bytes.extend_from_slice(data);
    let _ = proxcomp::checkpoint::decode(&bytes);
});
