//! Wire frame decode: the pure twin of the serving loop's streaming
//! frame reader. The length-prefix cap guard must reject oversized
//! declarations before any allocation; truncation must be a clean
//! `FrameErr::Bad`, never a panic or over-read.
#![no_main]

use libfuzzer_sys::fuzz_target;
use proxcomp::inference::net::{decode_frame, MAX_FRAME_BYTES};

fuzz_target!(|data: &[u8]| {
    // The serving cap (MAX_FRAME_BYTES) and a small cap: the latter
    // makes the cap-rejection branch reachable with tiny inputs.
    let _ = decode_frame(data, MAX_FRAME_BYTES);
    let _ = decode_frame(data, 64);
});
