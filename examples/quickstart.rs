//! Quickstart: compressed learning in ~40 lines.
//!
//! Trains the small MLP on synth-mnist with SpC (Prox-ADAM + in-graph
//! soft thresholding), prints the accuracy / compression trade-off, and
//! shows the layer table. Run with:
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use proxcomp::config::{Method, RunConfig};
use proxcomp::coordinator::sweep;
use proxcomp::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (built once by `make artifacts`).
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;

    // 2. Configure a short SpC run: λ controls compression.
    let cfg = RunConfig {
        model: "mlp".into(),
        method: Method::SpC,
        lambda: 0.4,
        lr: 1e-3,
        steps: 150,
        train_examples: 2048,
        test_examples: 512,
        ..RunConfig::default()
    };

    // 3. Train (starts from He-initialized random weights — no
    //    pre-trained model needed, the paper's key property).
    let result = sweep::run_method(&mut rt, &manifest, &cfg)?;

    // 4. Inspect.
    println!("\nquickstart: SpC on {}", result.model);
    println!("  accuracy          {:.4}", result.accuracy);
    println!(
        "  compression rate  {:.4}  ({:.0}× smaller)",
        result.compression_rate,
        result.times_factor()
    );
    println!("  nonzero weights   {} / {}", result.nnz, result.total_weights);
    println!("\n  layer       nnz / total");
    for (layer, nnz, total) in &result.layer_stats {
        println!("  {layer:<10} {nnz:>8} / {total}");
    }
    Ok(())
}
