//! Quickstart: compressed learning in ~40 lines.
//!
//! Trains the small MLP on synth-mnist with SpC (Prox-ADAM + soft
//! thresholding), prints the accuracy / compression trade-off, and
//! shows the layer table. Run with:
//!
//! ```bash
//! cargo run --release --example quickstart          # native CPU backend
//! make artifacts && cargo run --release --features pjrt --example quickstart
//! ```

use proxcomp::config::{Method, RunConfig};
use proxcomp::coordinator::sweep;
use proxcomp::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    // 1. Load the AOT artifacts (built by `make artifacts`); offline
    //    builds fall back to the built-in native-backend manifest.
    let manifest = Manifest::load_or_native("artifacts")?;
    let mut rt = Runtime::cpu()?;

    // 2. Configure a short SpC run: λ controls compression.
    let cfg = RunConfig {
        model: "mlp".into(),
        method: Method::SpC,
        lambda: 0.4,
        lr: 1e-3,
        steps: 150,
        train_examples: 2048,
        test_examples: 512,
        ..RunConfig::default()
    };

    // 3. Train (starts from He-initialized random weights — no
    //    pre-trained model needed, the paper's key property).
    let result = sweep::run_method(&mut rt, &manifest, &cfg)?;

    // 4. Inspect.
    println!("\nquickstart: SpC on {}", result.model);
    println!("  accuracy          {:.4}", result.accuracy);
    println!(
        "  compression rate  {:.4}  ({:.0}× smaller)",
        result.compression_rate,
        result.times_factor()
    );
    println!("  nonzero weights   {} / {}", result.nnz, result.total_weights);
    println!("\n  layer       nnz / total");
    for (layer, nnz, total) in &result.layer_stats {
        println!("  {layer:<10} {nnz:>8} / {total}");
    }
    Ok(())
}
