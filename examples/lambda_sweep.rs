//! λ sweep (a miniature of the paper's Figure 6a): accuracy and
//! compression rate as functions of the regularization weight.
//!
//! ```bash
//! cargo run --release --example lambda_sweep [-- --model mlp --steps 150]
//! ```

use proxcomp::config::RunConfig;
use proxcomp::coordinator::sweep;
use proxcomp::metrics;
use proxcomp::runtime::{Manifest, Runtime};
use proxcomp::util::cli::Args;
use proxcomp::util::json::Json;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let model = args.str_or("model", "mlp");
    let steps = args.usize_or("steps", 150)?;
    args.finish()?;

    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let cfg = RunConfig {
        model,
        steps,
        lr: 1e-3,
        train_examples: 2048,
        test_examples: 512,
        ..RunConfig::default()
    };
    let lambdas = [0.0, 0.25, 0.5, 1.0, 2.0, 4.0, 8.0];
    let results = sweep::lambda_sweep(&mut rt, &manifest, &cfg, &lambdas)?;

    println!("\nλ sweep on {} ({} steps each):", cfg.model, cfg.steps);
    println!("{:>6}  {:>9}  {:>9}  {:>10}", "λ", "accuracy", "rate", "nnz");
    let reference = results[0].accuracy; // λ=0 is the reference model
    for r in &results {
        let marker = if r.lambda > 0.0 && r.accuracy >= reference { "  ← ≥ ref" } else { "" };
        println!(
            "{:>6}  {:>9.4}  {:>9.4}  {:>10}{}",
            r.lambda, r.accuracy, r.compression_rate, r.nnz, marker
        );
    }
    println!(
        "\nreference (λ=0) accuracy: {reference:.4}\n\
         paper Figure 6a: small λ can *beat* the reference (regularization\n\
         mitigates overfitting); accuracy decays only at high compression."
    );

    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    let p = metrics::write_json_report(&format!("lambda_sweep_{}.json", cfg.model), &arr)?;
    println!("wrote {}", p.display());
    Ok(())
}
