//! End-to-end driver (DESIGN.md §9): the full system on a real workload.
//!
//! LeNet-5 at the paper's exact size (430,500 weights) on synth-mnist:
//!
//! 1. train several hundred Prox-ADAM steps with ℓ1 sparse coding,
//!    logging the loss curve and compression rate as they evolve;
//! 2. debias (retrain the survivors with frozen zeros);
//! 3. save a compressed CSR checkpoint and report the size reduction;
//! 4. reload it and serve inference through the rust CSR engine,
//!    checking logits parity with the XLA `infer` artifact;
//! 5. report dense vs compressed latency (the Table-3 scenario).
//!
//! ```bash
//! make artifacts && cargo run --release --example lenet_end_to_end
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use std::path::Path;

use proxcomp::compress::{debias, spc};
use proxcomp::config::RunConfig;
use proxcomp::coordinator::{trainer::StepScalars, Trainer};
use proxcomp::inference::Engine;
use proxcomp::runtime::{Manifest, Runtime};
use proxcomp::tensor::Tensor;
use proxcomp::util::json::Json;
use proxcomp::{checkpoint, metrics};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("LENET_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(400);
    let retrain_steps = steps / 4;
    // AOT artifacts when present; the native conv-capable CPU backend
    // otherwise, so this example runs offline end to end.
    let manifest = Manifest::load_or_native("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let cfg = RunConfig {
        model: "lenet".into(),
        lambda: 0.25,
        lr: 2e-3,
        steps,
        train_examples: 8192,
        test_examples: 1024,
        eval_every: (steps / 4).max(1),
        ..RunConfig::default()
    };

    println!("=== phase 1: SpC training ({} steps, λ={}) ===", cfg.steps, cfg.lambda);
    let mut trainer = Trainer::new(&manifest, &cfg)?;
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    spc::run_with_evals(&mut rt, &mut trainer, "train_prox_adam", cfg.steps, scalars, cfg.eval_every)?;
    let eval1 = trainer.evaluate(&mut rt)?;
    let rate1 = trainer.state.params.compression_rate();
    println!("after SpC: acc {:.4}, rate {:.4}", eval1.accuracy, rate1);

    println!("\n=== phase 2: debias ({retrain_steps} steps) ===");
    debias::retrain(&mut rt, &mut trainer, retrain_steps, 2e-4)?;
    let eval2 = trainer.evaluate(&mut rt)?;
    let rate2 = trainer.state.params.compression_rate();
    println!("after debias: acc {:.4}, rate {:.4}", eval2.accuracy, rate2);

    // Loss curve out to reports/ (the §End-to-end record).
    trainer
        .history
        .write_csv(&metrics::report_path("lenet_end_to_end_curve.csv"))?;

    println!("\n=== phase 3: compressed checkpoint ===");
    let ckpt_path = Path::new("reports/lenet_end_to_end.pxcp");
    let mut meta = Json::obj();
    meta.set("model", Json::from("lenet"))
        .set("dataset", Json::from("synth-mnist"))
        .set("method", Json::from("SpC(Retrain)"))
        .set("lambda", Json::from(cfg.lambda as f64))
        .set("accuracy", Json::from(eval2.accuracy));
    let payload = checkpoint::save(ckpt_path, &trainer.state.params, &meta)?;
    let dense_bytes = trainer.state.params.total_params() * 4;
    println!(
        "checkpoint: {} KB compressed vs {} KB dense ({:.1}× smaller)",
        payload / 1024,
        dense_bytes / 1024,
        dense_bytes as f64 / payload as f64
    );

    println!("\n=== phase 4: reload + rust CSR inference ===");
    let ck = checkpoint::load(ckpt_path)?;
    assert_eq!(ck.params.values, trainer.state.params.values, "checkpoint roundtrip");
    let sparse_engine = Engine::from_bundle("lenet", &ck.params, true)?;
    let dense_engine = Engine::from_bundle("lenet", &ck.params, false)?;

    // Parity vs the XLA infer path on one batch.
    let artifact = trainer.entry.artifact("infer")?.clone();
    let batch = artifact.batch;
    let mut xs = Vec::new();
    for i in 0..batch {
        xs.extend_from_slice(trainer.test_data.image(i % trainer.test_data.n));
    }
    let mut inputs = trainer.state.params.to_host_values();
    inputs.push(proxcomp::runtime::HostValue::F32 {
        shape: vec![batch, 1, 28, 28],
        data: xs.clone(),
    });
    let xla_logits = rt.execute(&artifact.file, &inputs)?[0].as_f32()?.to_vec();
    let x = Tensor::new(vec![batch, 1, 28, 28], xs);
    let engine_logits = sparse_engine.forward(&x)?;
    let max_diff = xla_logits
        .iter()
        .zip(&engine_logits.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("engine vs XLA logits: max |Δ| = {max_diff:.2e}");
    assert!(max_diff < 1e-2, "engine/XLA divergence: {max_diff}");

    println!("\n=== phase 5: dense vs compressed latency ===");
    let acc_sparse = sparse_engine.accuracy(&trainer.test_data, 64)?;
    for (name, engine) in [("dense", &dense_engine), ("sparse(CSR)", &sparse_engine)] {
        let t0 = std::time::Instant::now();
        let mut total = 0usize;
        let reps = 3;
        for _ in 0..reps {
            engine.accuracy(&trainer.test_data, 64)?;
            total += trainer.test_data.n;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "  {name:<12} model {:>6} KB, {:.1} examples/s",
            engine.model_size_bytes() / 1024,
            total as f64 / dt
        );
    }
    println!("\nCSR-engine accuracy: {acc_sparse:.4} (XLA eval: {:.4})", eval2.accuracy);
    println!("\nend-to-end OK");
    Ok(())
}
