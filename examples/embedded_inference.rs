//! Embedded-inference scenario (paper Section 4.5): deploy a compressed
//! model on a small device.
//!
//! Loads the checkpoint produced by `lenet_end_to_end` (or trains a quick
//! one if absent), then:
//!
//! * measures dense vs CSR inference wallclock on this machine,
//! * runs the roofline device model for ARM Mali-T860 and GTX 1080 Ti to
//!   estimate the paper's Table-3 speedups,
//! * prints the model-size comparison (paper: 148 KB vs 5.0 MB).
//!
//! ```bash
//! cargo run --release --example embedded_inference
//! ```

use std::path::Path;

use proxcomp::config::RunConfig;
use proxcomp::coordinator::sweep;
use proxcomp::data;
use proxcomp::device::{estimate_speedup, DeviceModel, GTX_1080TI, MALI_T860};
use proxcomp::inference::Engine;
use proxcomp::runtime::{Manifest, ParamBundle, Runtime};
use proxcomp::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let params = load_or_train()?;
    let rate = params.compression_rate();
    println!("model: lenet, compression rate {rate:.4}\n");

    let dense = Engine::from_bundle("lenet", &params, false)?;
    let sparse = Engine::from_bundle("lenet", &params, true)?;

    // --- model size (paper Table 3: 148 KB vs 5.0 MB for full MNIST LeNet)
    println!("model size:");
    println!("  dense       {:>8} KB", dense.model_size_bytes() / 1024);
    println!("  compressed  {:>8} KB", sparse.model_size_bytes() / 1024);

    // --- measured wallclock on this host (batch 1: the embedded case)
    let test = data::generate("synth-mnist", 256, 0x7E57_DA7A)?;
    println!("\nmeasured on this host (CPU engine, batch 1):");
    for (name, engine) in [("dense", &dense), ("compressed", &sparse)] {
        let x = Tensor::new(vec![1, 1, 28, 28], test.image(0).to_vec());
        // warmup
        engine.forward(&x)?;
        let t0 = std::time::Instant::now();
        let reps = 50;
        for i in 0..reps {
            let x = Tensor::new(vec![1, 1, 28, 28], test.image(i % test.n).to_vec());
            engine.forward(&x)?;
        }
        let per = t0.elapsed().as_secs_f64() / reps as f64;
        println!("  {name:<11} {:.3} ms/image", per * 1e3);
    }

    // --- roofline estimates for the paper's devices (batch 64: the
    // steady-state regime the paper's whole-test-set timings reflect)
    println!("\nroofline estimates (device cost model, batch 64):");
    println!("  device              dense        compressed   speedup");
    for dev in [&MALI_T860 as &DeviceModel, &GTX_1080TI] {
        let dense_work = dense.work_profile(64, 1, 28, 28);
        let sparse_work = sparse.work_profile(64, 1, 28, 28);
        let est = estimate_speedup(dev, &dense, &sparse, &dense_work, &sparse_work);
        println!(
            "  {:<18} {:>9.3} ms {:>9.3} ms   {:.2}×",
            est.device,
            est.dense_seconds * 1e3,
            est.sparse_seconds * 1e3,
            est.speedup()
        );
    }
    println!(
        "\npaper Table 3 (Lenet-5/MNIST): GTX 1080 Ti 1.98×, Mali-T860 1.2×\n\
         (absolute times differ — full MNIST model + their stack — but the\n\
         shape holds: modest speedup despite ~30× smaller weights, because\n\
         sparse kernels run at lower efficiency; see DESIGN.md §4)"
    );

    // --- per-layer timing table (where the time goes)
    println!("\nper-layer wallclock (batch 64, compressed engine):");
    let mut xs = Vec::new();
    for i in 0..64 {
        xs.extend_from_slice(test.image(i % test.n));
    }
    let x = Tensor::new(vec![64, 1, 28, 28], xs);
    let (_, timings) = sparse.forward_timed(&x)?;
    for t in timings {
        println!("  {:<10} {:>10.1} µs", t.name, t.micros);
    }
    Ok(())
}

/// Load the end-to-end checkpoint, or quickly train a compressed LeNet.
fn load_or_train() -> anyhow::Result<ParamBundle> {
    let path = Path::new("reports/lenet_end_to_end.pxcp");
    if path.exists() {
        println!("using checkpoint {}", path.display());
        return Ok(proxcomp::checkpoint::load(path)?.params);
    }
    println!("no checkpoint found; training a quick compressed LeNet...");
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let cfg = RunConfig {
        model: "lenet".into(),
        lambda: 0.25,
        lr: 2e-3,
        steps: 150,
        retrain_steps: 50,
        train_examples: 4096,
        test_examples: 512,
        ..RunConfig::default()
    };
    // Run SpC, then rebuild the params from a fresh trainer pass: the
    // controller API returns stats; for the engine we need weights, so we
    // drive the trainer directly here.
    let mut trainer = proxcomp::coordinator::Trainer::new(&manifest, &cfg)?;
    let scalars = proxcomp::coordinator::trainer::StepScalars {
        lambda: cfg.lambda,
        lr: cfg.lr,
        mu: 0.0,
    };
    trainer.run_steps(&mut rt, "train_prox_adam", cfg.steps, scalars, 0)?;
    proxcomp::compress::debias::retrain(&mut rt, &mut trainer, cfg.retrain_steps, 2e-4)?;
    let _ = sweep::run_method; // (see `quickstart` for the high-level API)
    Ok(trainer.state.params)
}
