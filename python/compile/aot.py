"""AOT driver: lower every (model × step) graph to HLO text + manifest.

Run once at build time (``make artifacts``); never imported at runtime.

Interchange is HLO **text** (not ``.serialize()``): jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the rust ``xla`` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Outputs under ``artifacts/``:

* ``{model}_{step}.hlo.txt`` — one per (model, step) pair.
* ``manifest.json`` — for every model: the parameter spec (name, kind,
  shape, prunable, layer), batch sizes, dataset id, and for every
  artifact the flat input/output role lists in exact HLO argument order.

The rust coordinator re-creates He-initialized parameters itself (from
the manifest's kind/shape info), so Python is not needed even for
initialization at runtime.

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts \
        [--models mlp,lenet] [--steps train_prox_adam,eval]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
from jax._src.lib import xla_client as xc

from . import steps as steps_mod
from .models import REGISTRY

# Per-model batch sizes, tuned for the CPU-PJRT testbed (DESIGN.md §4).
TRAIN_BATCH = {"mlp": 128, "lenet": 128, "alexnet_s": 64, "vgg_s": 64, "resnet_s": 64}
EVAL_BATCH = {"mlp": 256, "lenet": 256, "alexnet_s": 128, "vgg_s": 128, "resnet_s": 128}
DATASET = {
    "mlp": "synth-mnist",
    "lenet": "synth-mnist",
    "alexnet_s": "synth-cifar",
    "vgg_s": "synth-cifar",
    "resnet_s": "synth-cifar",
}

ALL_STEPS = list(steps_mod.BUILDERS)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_one(model, spec, step_name: str, batch: int):
    builder = steps_mod.BUILDERS[step_name]
    fn, args, in_roles, out_roles = builder(model, spec, batch)
    # keep_unused=True: jit would otherwise prune arguments that a graph
    # doesn't touch (e.g. the MM L-step ignores theta/lagrange leaves of
    # non-prunable parameters), silently breaking the manifest's
    # input-index ↔ parameter(i) contract with the rust runtime.
    lowered = jax.jit(fn, keep_unused=True).lower(*args)
    return to_hlo_text(lowered), in_roles, out_roles


def build_manifest_entry(name, model, spec):
    return {
        "model": name,
        "dataset": DATASET[name],
        "input_shape": list(model.INPUT_SHAPE),
        "num_classes": model.NUM_CLASSES,
        "train_batch": TRAIN_BATCH[name],
        "eval_batch": EVAL_BATCH[name],
        "params": spec,
        "num_weights": sum(
            _numel(s["shape"]) for s in spec if s["prunable"]
        ),
        "num_params": sum(_numel(s["shape"]) for s in spec),
        "artifacts": {},
    }


def _numel(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=",".join(REGISTRY))
    ap.add_argument("--steps", default=",".join(ALL_STEPS))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    model_names = [m for m in args.models.split(",") if m]
    step_names = [s for s in args.steps.split(",") if s]

    manifest = {"version": 1, "generated_unix": int(time.time()), "models": {}}
    t0 = time.time()
    for name in model_names:
        model = REGISTRY[name]
        _, spec = model.init(seed=0)
        entry = build_manifest_entry(name, model, spec)
        for step in step_names:
            batch = EVAL_BATCH[name] if step in ("eval", "infer") else TRAIN_BATCH[name]
            t1 = time.time()
            hlo, in_roles, out_roles = lower_one(model, spec, step, batch)
            fname = f"{name}_{step}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(hlo)
            entry["artifacts"][step] = {
                "file": fname,
                "batch": batch,
                "inputs": in_roles,
                "outputs": out_roles,
                "sha256": hashlib.sha256(hlo.encode()).hexdigest()[:16],
                "bytes": len(hlo),
            }
            print(
                f"[aot] {fname:44s} {len(hlo)/1e6:7.2f} MB  {time.time()-t1:6.1f}s",
                flush=True,
            )
        manifest["models"][name] = entry

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {mpath}; total {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
