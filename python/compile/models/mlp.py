"""3-layer MLP for the quickstart path (synth-mnist, flattened input).

Small enough that a full SpC train/debias/compress cycle runs in seconds
on the CPU PJRT client; the FC layers exercise both paper kernels.
"""

from __future__ import annotations

from . import common as C

NAME = "mlp"
INPUT_SHAPE = (1, 28, 28)
NUM_CLASSES = 10
HIDDEN = (256, 128)


def init(seed: int = 0):
    b = C.ParamBuilder(seed)
    nin = 28 * 28
    b.fc("fc1", nin, HIDDEN[0])
    b.fc("fc2", HIDDEN[0], HIDDEN[1])
    b.fc("fc3", HIDDEN[1], NUM_CLASSES)
    return b.build()


def apply(params, x):
    """``x``: (B, 1, 28, 28) NCHW (flattened internally)."""
    fc1_w, fc1_b, fc2_w, fc2_b, fc3_w, fc3_b = params
    h = C.flatten(x)
    h = C.relu(C.fc(h, fc1_w, fc1_b))
    h = C.relu(C.fc(h, fc2_w, fc2_b))
    return C.fc(h, fc3_w, fc3_b)
