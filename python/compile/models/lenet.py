"""LeNet-5 (Caffe variant) — kept at the paper's exact layer sizes.

Matches Table A1 of the paper: conv1 5×5×1×20 (500 weights), conv2
5×5×20×50 (25,000), fc1 800×500 (400,000), fc2 500×10 (5,000); total
430,500 prunable weights. Input 28×28 grey (MNIST-shaped), valid-padding
convs with 2×2 max pools: 28→24→12→8→4.
"""

from __future__ import annotations

from . import common as C

NAME = "lenet"
INPUT_SHAPE = (1, 28, 28)
NUM_CLASSES = 10


def init(seed: int = 0):
    b = C.ParamBuilder(seed)
    b.conv("conv1", 1, 20, 5, 5)
    b.conv("conv2", 20, 50, 5, 5)
    b.fc("fc1", 50 * 4 * 4, 500)
    b.fc("fc2", 500, NUM_CLASSES)
    return b.build()


def apply(params, x):
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = C.conv2d(x, c1w, c1b, pad=0)  # (B,20,24,24)
    h = C.max_pool(h)  # (B,20,12,12)
    h = C.conv2d(h, c2w, c2b, pad=0)  # (B,50,8,8)
    h = C.max_pool(h)  # (B,50,4,4)
    h = C.flatten(h)
    h = C.relu(C.fc(h, f1w, f1b))
    return C.fc(h, f2w, f2b)
