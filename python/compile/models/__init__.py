"""L2 model zoo registry.

Every model module exposes ``NAME``, ``INPUT_SHAPE`` (C, H, W),
``NUM_CLASSES``, ``init(seed) -> (params, spec)`` and
``apply(params, x) -> logits``. ``REGISTRY`` maps name → module; the AOT
driver and the tests iterate it.
"""

from . import alexnet, lenet, mlp, resnet, vgg  # noqa: F401

REGISTRY = {m.NAME: m for m in (mlp, lenet, alexnet, vgg, resnet)}


def get(name: str):
    if name not in REGISTRY:
        raise KeyError(f"unknown model {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]
