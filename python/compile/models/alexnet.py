"""AlexNet-S: width-scaled CIFAR AlexNet (DESIGN.md §4 substitution).

Preserves the paper network's depth structure (5 conv + 3 fc, pools after
conv1/conv2/conv5) with channel widths scaled for the CPU-PJRT testbed.
The paper's full-width CIFAR variant (Table A2, 7,558,176 weights) is
recorded in ``FULL_WIDTHS`` for reporting; default runs use ``WIDTHS``.
"""

from __future__ import annotations

from . import common as C

NAME = "alexnet_s"
INPUT_SHAPE = (3, 32, 32)
NUM_CLASSES = 10

# (conv1..conv5 channels), (fc1, fc2 widths)
WIDTHS = ((32, 64, 96, 96, 64), (256, 128))
FULL_WIDTHS = ((96, 128, 768, 96, 256), (1024, 1024))  # paper Table A2 shapes


def init(seed: int = 0):
    (c1, c2, c3, c4, c5), (f1, f2) = WIDTHS
    b = C.ParamBuilder(seed)
    b.conv("conv1", 3, c1, 5, 5)
    b.conv("conv2", c1, c2, 5, 5)
    b.conv("conv3", c2, c3, 3, 3)
    b.conv("conv4", c3, c4, 3, 3)
    b.conv("conv5", c4, c5, 3, 3)
    # 32 →(pool)16 →(pool)8 →(pool)4 spatial
    b.fc("fc1", c5 * 4 * 4, f1)
    b.fc("fc2", f1, f2)
    b.fc("fc3", f2, NUM_CLASSES)
    return b.build()


def apply(params, x):
    (c1w, c1b, c2w, c2b, c3w, c3b, c4w, c4b, c5w, c5b,
     f1w, f1b, f2w, f2b, f3w, f3b) = params
    h = C.relu(C.conv2d(x, c1w, c1b, pad=2))
    h = C.max_pool(h)  # 16
    h = C.relu(C.conv2d(h, c2w, c2b, pad=2))
    h = C.max_pool(h)  # 8
    h = C.relu(C.conv2d(h, c3w, c3b, pad=1))
    h = C.relu(C.conv2d(h, c4w, c4b, pad=1))
    h = C.relu(C.conv2d(h, c5w, c5b, pad=1))
    h = C.max_pool(h)  # 4
    h = C.flatten(h)
    h = C.relu(C.fc(h, f1w, f1b))
    h = C.relu(C.fc(h, f2w, f2b))
    return C.fc(h, f3w, f3b)
