"""ResNet-S: depth-scaled CIFAR ResNet (DESIGN.md §4 substitution).

The paper uses ResNet-32 = conv1 + 3 stages × 5 basic blocks (2 convs
each) at widths 16/32/64 + fc (Table A4, 464,432 weights). We keep the
exact stage widths and block structure but default to ``N_BLOCKS = 2``
blocks per stage (ResNet-14) for the CPU-PJRT testbed; stage-2/3 first
blocks use 1×1 projection shortcuts exactly as Table A4's ``conv*-proj``
rows. BatchNorm uses batch statistics (stateless; DESIGN.md §4).
"""

from __future__ import annotations

from . import common as C

NAME = "resnet_s"
INPUT_SHAPE = (3, 32, 32)
NUM_CLASSES = 10

WIDTHS = (16, 32, 64)
N_BLOCKS = 2  # paper: 5 (ResNet-32); ours: 2 (ResNet-14)


def init(seed: int = 0):
    b = C.ParamBuilder(seed)
    b.conv("conv1", 3, WIDTHS[0], 3, 3)
    b.bn("bn1", WIDTHS[0])
    cin = WIDTHS[0]
    for si, w in enumerate(WIDTHS, start=1):
        for bi in range(1, N_BLOCKS + 1):
            b.conv(f"conv{si}-{bi}-1", cin, w, 3, 3)
            b.bn(f"bn{si}-{bi}-1", w)
            b.conv(f"conv{si}-{bi}-2", w, w, 3, 3)
            b.bn(f"bn{si}-{bi}-2", w)
            if bi == 1 and cin != w:
                b.conv(f"conv{si}-{bi}-proj", cin, w, 1, 1)
            cin = w
    b.fc("fc1", WIDTHS[-1], NUM_CLASSES)
    return b.build()


def apply(params, x):
    i = 0

    def take(n):
        nonlocal i
        out = params[i : i + n]
        i += n
        return out

    c1w, c1b, s1, b1 = take(4)
    h = C.relu(C.batch_norm(C.conv2d(x, c1w, c1b, pad=1), s1, b1))
    cin = WIDTHS[0]
    for si, w in enumerate(WIDTHS, start=1):
        for bi in range(1, N_BLOCKS + 1):
            stride = 2 if (bi == 1 and si > 1) else 1
            cw1, cb1, sc1, sb1 = take(4)
            cw2, cb2, sc2, sb2 = take(4)
            y = C.relu(C.batch_norm(C.conv2d(h, cw1, cb1, stride=stride, pad=1), sc1, sb1))
            y = C.batch_norm(C.conv2d(y, cw2, cb2, pad=1), sc2, sb2)
            if bi == 1 and cin != w:
                # 1x1 projection shortcut (Table A4's conv*-proj rows).
                pw, pb = take(2)
                h = C.conv2d(h, pw, pb, stride=stride, pad=0)
            h = C.relu(h + y)
            cin = w
    h = C.avg_pool_global(h)
    fw, fb = take(2)
    return C.fc(h, fw, fb)
