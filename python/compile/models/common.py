"""Shared building blocks for the L2 model zoo.

All models are purely functional: ``init(seed) -> (params, spec)`` and
``apply(params, x) -> logits`` where ``params`` is a flat *list* of
arrays (flattening order = spec order = the artifact argument order the
rust coordinator relies on) and ``spec`` is a list of dicts describing
each leaf (name, kind, shape, prunable flag, layer name).

Fully-connected layers run through the L1 Pallas kernels with a custom
VJP that mirrors the paper exactly (Section 3.2):

    forward : ``X_T = X_B @ W'``      — Figure-2 kernel (``spmm.dxct``)
    backward: ``dL/dX_B = dL/dX_T @ W`` — Figure-3 kernel (``spmm.dxc``)

so both paper kernels lower into every training artifact. Convolutions
use ``lax.conv_general_dilated`` (NCHW); the element-level CSR conv path
lives in the rust inference engine (im2col + CSR), per DESIGN.md §3.

Weight initialization is He et al. 2015 (the paper Section 4 uses it for
its ReLU networks). Biases start at zero and are *not* prunable — the
paper's layer-wise tables (A1-A4) count weights only.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import spmm


# ---------------------------------------------------------------------------
# Fully-connected layer through the Pallas kernels (paper Figs. 2-3)
# ---------------------------------------------------------------------------


@jax.custom_vjp
def fc_apply(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """``x (B,K) @ w' (K,N) -> (B,N)`` with Caffe row-major weights (N,K)."""
    return spmm.dxct(x, w)


def _fc_fwd(x, w):
    return spmm.dxct(x, w), (x, w)


def _fc_bwd(res, g):
    x, w = res
    dx = spmm.dxc(g, w)  # paper Figure 3: dense-gradient × compressed
    dw = jnp.dot(g.T, x, preferred_element_type=jnp.float32)  # (N,K) dense
    return dx, dw


fc_apply.defvjp(_fc_fwd, _fc_bwd)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def he_normal(rng: np.random.Generator, shape, fan_in: int) -> np.ndarray:
    """He et al. 2015 normal init: std = sqrt(2 / fan_in)."""
    std = np.sqrt(2.0 / fan_in)
    return (rng.standard_normal(shape) * std).astype(np.float32)


class ParamBuilder:
    """Accumulates (params, spec) pairs in a fixed flattening order."""

    def __init__(self, seed: int):
        self.rng = np.random.default_rng(seed)
        self.params: list[np.ndarray] = []
        self.spec: list[dict] = []

    def _add(self, name, kind, arr, prunable, layer):
        self.params.append(arr)
        self.spec.append(
            {
                "name": name,
                "kind": kind,
                "shape": list(arr.shape),
                "prunable": bool(prunable),
                "layer": layer,
            }
        )
        return len(self.params) - 1

    def conv(self, layer: str, cin: int, cout: int, kh: int, kw: int):
        fan_in = cin * kh * kw
        self._add(f"{layer}_w", "conv_w", he_normal(self.rng, (cout, cin, kh, kw), fan_in), True, layer)
        self._add(f"{layer}_b", "conv_b", np.zeros((cout,), np.float32), False, layer)

    def fc(self, layer: str, nin: int, nout: int):
        # Caffe row-major layout (N_out, N_in) — what the CSR kernels expect.
        self._add(f"{layer}_w", "fc_w", he_normal(self.rng, (nout, nin), nin), True, layer)
        self._add(f"{layer}_b", "fc_b", np.zeros((nout,), np.float32), False, layer)

    def bn(self, layer: str, c: int):
        self._add(f"{layer}_scale", "bn_scale", np.ones((c,), np.float32), False, layer)
        self._add(f"{layer}_bias", "bn_bias", np.zeros((c,), np.float32), False, layer)

    def build(self):
        return self.params, self.spec


# ---------------------------------------------------------------------------
# Layer ops (NCHW)
# ---------------------------------------------------------------------------


def conv2d(x, w, b, stride=1, pad=0):
    """NCHW conv with OIHW weights + per-channel bias.

    ``pad`` is an explicit symmetric padding amount (PyTorch-style), NOT
    "SAME": jax's SAME pads *asymmetrically* for stride-2 windows, which
    the rust inference engine (symmetric im2col padding) could not mirror
    bit-for-bit. Explicit symmetric padding keeps the two backends
    numerically identical — the parity tests depend on it.
    """
    y = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y + b.reshape(1, -1, 1, 1)


def max_pool(x, size=2, stride=2):
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, 1, size, size),
        window_strides=(1, 1, stride, stride),
        padding="VALID",
    )


def avg_pool_global(x):
    """Global average pool NCHW -> (B, C)."""
    return jnp.mean(x, axis=(2, 3))


def batch_norm(x, scale, bias, eps=1e-5):
    """Batch-statistics normalization over (N, H, W) per channel.

    No running averages: eval batches are large enough on this testbed
    and it keeps the artifact state stateless (DESIGN.md §4).
    """
    mean = jnp.mean(x, axis=(0, 2, 3), keepdims=True)
    var = jnp.var(x, axis=(0, 2, 3), keepdims=True)
    xn = (x - mean) * lax.rsqrt(var + eps)
    return xn * scale.reshape(1, -1, 1, 1) + bias.reshape(1, -1, 1, 1)


def relu(x):
    return jnp.maximum(x, 0.0)


def flatten(x):
    return x.reshape(x.shape[0], -1)


def fc(x, w, b):
    """Fully-connected layer via the paper's kernels + bias."""
    return fc_apply(x, w) + b.reshape(1, -1)


# ---------------------------------------------------------------------------
# Loss / metrics used by steps.py
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits, labels):
    """Mean softmax CE; ``labels`` int32 class ids ``(B,)``."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)


def correct_count(logits, labels):
    return jnp.sum((jnp.argmax(logits, axis=-1) == labels).astype(jnp.int32))
