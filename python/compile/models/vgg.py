"""VGG-S: width-scaled VGG16 for CIFAR (DESIGN.md §4 substitution).

Keeps VGG16's 13-conv/3-fc depth and the 5-stage 2× pooling schedule
(32→16→8→4→2→1) with channel widths at 1/4 of the paper's (Table A3
shapes in ``FULL_WIDTHS`` for reporting).
"""

from __future__ import annotations

from . import common as C

NAME = "vgg_s"
INPUT_SHAPE = (3, 32, 32)
NUM_CLASSES = 10

# conv widths per stage (VGG16 = 2,2,3,3,3 convs per stage), then fc widths
STAGES = ((16, 2), (32, 2), (64, 3), (128, 3), (128, 3))
FCS = (256, 256)
FULL_STAGES = ((64, 2), (128, 2), (256, 3), (512, 3), (512, 3))  # paper Table A3
FULL_FCS = (1024, 1024)


def _conv_names():
    names = []
    for si, (_, n) in enumerate(STAGES, start=1):
        for ci in range(1, n + 1):
            names.append(f"conv{si}-{ci}")
    return names


def init(seed: int = 0):
    b = C.ParamBuilder(seed)
    cin = 3
    for si, (w, n) in enumerate(STAGES, start=1):
        for ci in range(1, n + 1):
            b.conv(f"conv{si}-{ci}", cin, w, 3, 3)
            cin = w
    b.fc("fc1", STAGES[-1][0] * 1 * 1, FCS[0])
    b.fc("fc2", FCS[0], FCS[1])
    b.fc("fc3", FCS[1], NUM_CLASSES)
    return b.build()


def apply(params, x):
    i = 0
    h = x
    for w, n in STAGES:
        for _ in range(n):
            h = C.relu(C.conv2d(h, params[i], params[i + 1], pad=1))
            i += 2
        h = C.max_pool(h)
    h = C.flatten(h)
    h = C.relu(C.fc(h, params[i], params[i + 1])); i += 2
    h = C.relu(C.fc(h, params[i], params[i + 1])); i += 2
    return C.fc(h, params[i], params[i + 1])
