"""L2 optimizers: proximal minibatch algorithms from the paper + baselines.

Implements, over flat lists of parameter leaves:

* :func:`prox_sgd` — proximal stochastic gradient (paper Eq. 2).
* :func:`prox_rmsprop` — **Algorithm 1** (Prox-RMSProp).
* :func:`prox_adam` — **Algorithm 2** (Prox-ADAM).
* :func:`masked_adam` — debias / retraining step (Section 2.4): ADAM with
  λ=0 and a 0/1 mask freezing pruned weights at exactly zero. Also used
  for the Pru baseline's retraining phase (Han et al. 2015).
* :func:`mm_lstep` — the L-step of the MM baseline (Carreira-Perpiñán &
  Idelbayev 2018): SGD-with-momentum on the augmented Lagrangian
  ``L(w) + μ/2 ‖w − θ − λ/μ‖²``. The C-step (soft-threshold of
  ``w − λ/μ``) and the multiplier ascent run host-side in the rust
  coordinator (`rust/src/compress/mm.rs`) every few thousand steps, as in
  the paper.

The proximal operator is the L1 Pallas kernel
(:func:`..kernels.prox.soft_threshold`), so it lowers into the same HLO
artifact as the update — there is no separate "prox pass" at runtime.

Only leaves flagged ``prunable`` in the model spec receive the prox /
mask treatment (weights); biases and BN parameters follow the plain
update, matching the paper's layer tables which count weights only.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import prox

# Paper-standard hyperparameters (Hinton lecture 6e / Kingma & Ba 2015).
RMSPROP_BETA = 0.9
ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
EPS = 1e-8
MM_MOMENTUM = 0.9


def _maybe_prox(w, prunable: bool, thresh):
    return prox.soft_threshold(w, thresh) if prunable else w


def prox_sgd(params, grads, prunable, lam, lr):
    """``w ← prox_{η λ ‖·‖₁}(w − η g)`` (paper Eq. 2)."""
    new_params = []
    for p, g, pr in zip(params, grads, prunable):
        w = p - lr * g
        new_params.append(_maybe_prox(w, pr, lr * lam))
    return new_params


def prox_rmsprop(params, grads, v, prunable, lam, lr, beta=RMSPROP_BETA, eps=EPS):
    """Algorithm 1 (Prox-RMSProp). Returns ``(params', v')``."""
    new_params, new_v = [], []
    for p, g, vi, pr in zip(params, grads, v, prunable):
        vi2 = beta * vi + (1.0 - beta) * g * g
        w = p - lr * g / (jnp.sqrt(vi2) + eps)
        new_params.append(_maybe_prox(w, pr, lr * lam))
        new_v.append(vi2)
    return new_params, new_v


def prox_adam(
    params, grads, m, v, t, prunable, lam, lr,
    beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=EPS,
):
    """Algorithm 2 (Prox-ADAM). ``t`` is the f32 rank-0 timestep *before*
    this update. Returns ``(params', m', v', t+1)``."""
    t2 = t + 1.0
    bc1 = 1.0 - jnp.power(beta1, t2)
    bc2 = 1.0 - jnp.power(beta2, t2)
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi, pr in zip(params, grads, m, v, prunable):
        mi2 = beta1 * mi + (1.0 - beta1) * g
        vi2 = beta2 * vi + (1.0 - beta2) * g * g
        mhat = mi2 / bc1
        vhat = vi2 / bc2
        w = p - lr * mhat / (jnp.sqrt(vhat) + eps)
        new_params.append(_maybe_prox(w, pr, lr * lam))
        new_m.append(mi2)
        new_v.append(vi2)
    return new_params, new_m, new_v, t2


def masked_adam(
    params, grads, m, v, t, masks, lr,
    beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=EPS,
):
    """Debias / retrain step: ADAM restricted to surviving weights.

    ``masks`` has one 0/1 array per leaf (all-ones for non-prunable
    leaves). Gradients are masked *before* entering the moments so frozen
    weights accumulate no momentum, and parameters are masked after the
    update — pruned weights remain exactly 0.0 (Section 2.4).
    """
    t2 = t + 1.0
    bc1 = 1.0 - jnp.power(beta1, t2)
    bc2 = 1.0 - jnp.power(beta2, t2)
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi, mk in zip(params, grads, m, v, masks):
        g = g * mk
        mi2 = beta1 * mi + (1.0 - beta1) * g
        vi2 = beta2 * vi + (1.0 - beta2) * g * g
        mhat = mi2 / bc1
        vhat = vi2 / bc2
        w = (p - lr * mhat / (jnp.sqrt(vhat) + eps)) * mk
        new_params.append(w)
        new_m.append(mi2)
        new_v.append(vi2)
    return new_params, new_m, new_v, t2


def mm_lstep(params, grads, mom, theta, lag, prunable, mu, lr, momentum=MM_MOMENTUM):
    """MM baseline L-step: SGD-momentum on the augmented Lagrangian.

    Gradient of ``L(w) + μ/2‖w − θ‖² − λᵀ(w − θ)`` w.r.t. ``w`` is
    ``∇L(w) + μ(w − θ) − λ``; the quadratic pull applies to prunable
    leaves only (θ/λ are zero-shaped copies for the others but unused).
    Returns ``(params', mom')``.
    """
    new_params, new_mom = [], []
    for p, g, mo, th, lg, pr in zip(params, grads, mom, theta, lag, prunable):
        if pr:
            g = g + mu * (p - th) - lg
        mo2 = momentum * mo + g
        new_params.append(p - lr * mo2)
        new_mom.append(mo2)
    return new_params, new_mom
