"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the ground truth against which the Pallas kernels are checked in
``python/tests/test_kernels.py`` (assert_allclose, hypothesis shape/value
sweeps). Keep them dead simple: no tiling, no tricks — just the math.

The three operations mirror the paper's three OpenCL kernels:

* :func:`soft_threshold` — Figure 4, the elementwise proximal operator of
  ``lambda * ||w||_1``.
* :func:`dense_x_compressed_t` — Figure 2, ``X_T = X_B @ W'`` (forward).
* :func:`dense_x_compressed` — Figure 3, ``dL/dX_B = dL/dX_T @ W``
  (backward).

In the reference the "compressed" operand is simply a dense array that
happens to contain zeros; the compressed *storage* formats live on the
rust side (``rust/src/sparse``) and in the block-sparse Pallas kernel
(:mod:`.spmm`).
"""

from __future__ import annotations

import jax.numpy as jnp


def soft_threshold(x: jnp.ndarray, thresh) -> jnp.ndarray:
    """Proximal operator of ``thresh * ||.||_1`` (soft thresholding).

    ``[prox(x)]_i = sgn(x_i) * max(|x_i| - thresh, 0)``.

    ``thresh`` may be a python float or a rank-0 array; it is typically
    ``learning_rate * lambda`` (see Algorithms 1-2 in the paper).
    """
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - thresh, 0.0)


def soft_threshold_clip_form(x: jnp.ndarray, thresh) -> jnp.ndarray:
    """The paper's Figure-4 formulation of the same operator.

    ``min(max(x - t, 0), x + t)`` — algebraically identical to
    :func:`soft_threshold`; kept as an independent oracle so the tests can
    cross-check the two formulations against each other.
    """
    return jnp.minimum(jnp.maximum(x - thresh, 0.0), x + thresh)


def dense_x_compressed_t(dmat: jnp.ndarray, cmat: jnp.ndarray) -> jnp.ndarray:
    """Forward-pass product ``Dmat @ Cmat'`` (paper Figure 2).

    ``dmat``: dense activations, shape ``(B, K)``.
    ``cmat``: (conceptually compressed) weight matrix, shape ``(N, K)``
    stored row-wise as in Caffe; the product contracts over ``K``.
    Result shape ``(B, N)``.
    """
    return dmat @ cmat.T


def dense_x_compressed(dmat: jnp.ndarray, cmat: jnp.ndarray) -> jnp.ndarray:
    """Backward-pass product ``Dmat @ Cmat`` (paper Figure 3).

    ``dmat``: upstream gradient ``dL/dX_T``, shape ``(B, N)``.
    ``cmat``: weight matrix, shape ``(N, K)``.
    Result ``dL/dX_B``, shape ``(B, K)``.
    """
    return dmat @ cmat


def masked_update(w: jnp.ndarray, step: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Debias / retrain update: apply ``step`` only where ``mask`` is 1.

    Zero-valued (pruned) weights stay exactly zero — the paper's
    retraining rule (Section 2.4): "the weights at the zero value are
    fixed and not updated during retraining".
    """
    return (w - step) * mask


def bsr_to_dense(values, col_idx, n_block_cols: int) -> jnp.ndarray:
    """Expand a Block-ELL matrix back to dense (oracle for the BSR kernel).

    ``values``: ``(n_block_rows, max_blocks, bh, bw)`` nonzero tiles.
    ``col_idx``: ``(n_block_rows, max_blocks)`` int32 block-column index of
    each tile; ``-1`` marks a padding slot (contributes nothing).
    Returns dense ``(n_block_rows * bh, n_block_cols * bw)``.
    """
    n_br, max_b, bh, bw = values.shape
    dense = jnp.zeros((n_br * bh, n_block_cols * bw), values.dtype)
    for i in range(n_br):
        for s in range(max_b):
            j = int(col_idx[i, s])
            if j >= 0:
                dense = dense.at[i * bh : (i + 1) * bh, j * bw : (j + 1) * bw].add(
                    values[i, s]
                )
    return dense


def bsr_matmul_ref(dmat, values, col_idx, n_block_cols: int) -> jnp.ndarray:
    """Oracle for the Block-ELL ``Dmat @ Cmat'`` kernel: densify then matmul."""
    dense = bsr_to_dense(values, col_idx, n_block_cols)
    return dmat @ dense.T
