"""L1 Pallas kernels: prox soft-threshold, compressed matmuls, oracles."""

from . import prox, ref, spmm  # noqa: F401
