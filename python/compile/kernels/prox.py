"""Pallas kernel for the l1 proximal operator (soft thresholding).

This is the TPU re-expression of the paper's Figure-4 OpenCL kernel.

OpenCL → Pallas mapping (DESIGN.md §3):
  * the OpenCL kernel assigns a *thread group* per matrix row and a
    *thread* per column, each lane touching one ``double`` in global
    memory;
  * on TPU the same computation is an elementwise VPU op over VMEM
    tiles — the grid iterates row-blocks, ``BlockSpec`` stages one
    ``(block_rows, cols)`` tile of the weight matrix from HBM into VMEM,
    and the whole tile is thresholded with vector ops (8×128 VPU lanes).

The threshold ``t = learning_rate * lambda`` is passed as a (1, 1) array
so a single lowered artifact serves every (lr, λ) sweep point.

Lowered with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls; interpret mode lowers to plain HLO, which is what
``aot.py`` embeds into the training-step artifacts.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per grid step. 256 f32 rows × 128-lane tiles keeps the staged tile
# well under VMEM (≈16 MB) for every weight matrix in this repo while
# filling the VPU; see DESIGN.md §10 for the footprint table.
DEFAULT_BLOCK_ROWS = 256


def _prox_kernel(x_ref, t_ref, o_ref):
    """Elementwise soft threshold of one VMEM tile.

    Uses the paper's clip formulation ``min(max(x - t, 0), x + t)``
    (Figure 4), which is branch-free and maps to two VPU min/max ops.
    """
    t = t_ref[0, 0]
    x = x_ref[...]
    o_ref[...] = jnp.minimum(jnp.maximum(x - t, 0.0), x + t)


def soft_threshold_2d(x: jnp.ndarray, thresh: jnp.ndarray, block_rows: int | None = None) -> jnp.ndarray:
    """Soft-threshold a 2-D array via the Pallas kernel.

    ``x``: ``(rows, cols)`` f32. ``thresh``: rank-0 or (1,1) f32.
    Grid over row-blocks; each step stages a ``(block_rows, cols)`` tile.
    """
    rows, cols = x.shape
    br = min(block_rows or DEFAULT_BLOCK_ROWS, rows)
    grid = (pl.cdiv(rows, br),)
    t2 = jnp.asarray(thresh, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _prox_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), x.dtype),
        interpret=True,
    )(x, t2)


def soft_threshold(x: jnp.ndarray, thresh) -> jnp.ndarray:
    """Soft-threshold an array of any rank via the 2-D Pallas kernel.

    Conv weights ``(O, I, H, W)`` and biases ``(n,)`` are viewed as 2-D
    (leading dim × rest) without copying; rank-0 thresholds broadcast.
    This is the entry point the optimizers in ``optim.py`` call, so the
    prox lowers into the same HLO as the surrounding update step.
    """
    orig_shape = x.shape
    if x.ndim == 0:
        x2 = x.reshape(1, 1)
    elif x.ndim == 1:
        x2 = x.reshape(1, -1)
    elif x.ndim == 2:
        x2 = x
    else:
        x2 = x.reshape(x.shape[0], -1)
    out = soft_threshold_2d(x2, jnp.asarray(thresh, jnp.float32))
    return out.reshape(orig_shape)
