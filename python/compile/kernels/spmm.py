"""Pallas matmul kernels for compressed weight matrices.

Re-expression of the paper's Figure-2 (``Dmat × Cmat'``, forward) and
Figure-3 (``Dmat × Cmat``, backward) OpenCL kernels for the TPU memory
hierarchy (DESIGN.md §3):

* **Tiled dense kernels** (:func:`dxct`, :func:`dxc`) — used inside the
  training graphs. During training the weights are *dense buffers with
  explicit zeros* (exactly the paper's setting: prox writes zeros into the
  ViennaCL matrix each step); the kernels tile the product for the MXU
  with an accumulation grid over K. The OpenCL thread-group/row ↦ grid
  tile mapping, scalar MAD loop ↦ per-tile ``jnp.dot`` (128×128 systolic
  array).

* **Block-ELL kernel** (:func:`bsr_dxct`) — the compressed-*storage*
  analogue of the paper's CSR kernel for inference. Unstructured CSR
  cannot feed the MXU (it wants dense tiles), so the TPU-honest port
  stores only nonzero *blocks* in an ELL-like layout with a fixed number
  of block slots per block-row; a per-slot block-column index drives the
  HBM→VMEM gather (the Pallas analogue of ``Cmat_row_ptrs``). Padding
  slots carry index ``-1`` and zero tiles. The paper rejected
  element-level ELL because element rows have wildly varying NNZ; at
  *block* granularity row populations concentrate (see
  ``rust/src/sparse/blockell.rs`` stats helpers), and static shapes are
  mandatory on TPU anyway.

All kernels are lowered ``interpret=True`` (CPU PJRT cannot run Mosaic
custom-calls); correctness is pinned to ``ref.py`` by
``python/tests/test_kernels.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile sizes. f32 accumulation; bm×bk and bk×bn tiles both fit
# VMEM comfortably (3 tiles × 128×512×4B ≈ 0.8 MB with default sizes).
DEF_BM = 128
DEF_BN = 128
DEF_BK = 512


def _matmul_kernel(x_ref, w_ref, o_ref, *, transpose_w: bool):
    """One (bm, bn) output tile, accumulating over the K grid axis.

    Grid layout: (m, n, k) with K innermost so the output tile stays
    resident in VMEM across the accumulation (``o_ref`` revisits).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = w_ref[...]
    if transpose_w:
        o_ref[...] += jnp.dot(x, w.T, preferred_element_type=jnp.float32)
    else:
        o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _tiled_matmul(x, w, transpose_w, bm, bn, bk):
    m, k = x.shape
    if transpose_w:
        n, k2 = w.shape
    else:
        k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    # Zero-pad the contraction axis to a tile multiple: interpret-mode
    # Pallas fills out-of-bounds *reads* with NaN (deliberately, to expose
    # masking bugs), and unlike the M/N axes — where NaN rows/cols land in
    # out-of-bounds outputs and are dropped on write — a ragged K tile
    # would poison every valid output it contracts into.
    if k % bk:
        pad = bk * pl.cdiv(k, bk) - k
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)) if transpose_w else ((0, pad), (0, 0)))
        k += pad
    grid = (pl.cdiv(m, bm), pl.cdiv(n, bn), pl.cdiv(k, bk))
    if transpose_w:
        w_spec = pl.BlockSpec((bn, bk), lambda i, j, l: (j, l))
    else:
        w_spec = pl.BlockSpec((bk, bn), lambda i, j, l: (l, j))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, transpose_w=transpose_w),
        grid=grid,
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)), w_spec],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def dxct(dmat: jnp.ndarray, cmat: jnp.ndarray, bm=DEF_BM, bn=DEF_BN, bk=DEF_BK) -> jnp.ndarray:
    """Forward product ``Dmat @ Cmat'`` (paper Figure 2).

    ``dmat``: activations ``(B, K)``; ``cmat``: weights ``(N, K)``
    (Caffe row-major layout). Returns ``(B, N)``.
    """
    return _tiled_matmul(dmat, cmat, True, bm, bn, bk)


def dxc(dmat: jnp.ndarray, cmat: jnp.ndarray, bm=DEF_BM, bn=DEF_BN, bk=DEF_BK) -> jnp.ndarray:
    """Backward product ``Dmat @ Cmat`` (paper Figure 3).

    ``dmat``: upstream gradient ``(B, N)``; ``cmat``: weights ``(N, K)``.
    Returns ``(B, K)``. On TPU this needs no special columnwise handling
    (the OpenCL kernel's un-coalesced access problem): BlockSpec stages
    ``(bk_of_N, bn_of_K)`` tiles and the MXU contracts over N directly.
    """
    return _tiled_matmul(dmat, cmat, False, bm, bn, bk)


# ---------------------------------------------------------------------------
# Block-ELL (BSR-with-fixed-slots) compressed kernel
# ---------------------------------------------------------------------------


def _bsr_kernel(x_ref, val_ref, idx_ref, o_ref, *, bh: int, bw: int, max_blocks: int):
    """One (bm, bh) output tile = sum over the nonzero blocks of one
    block-row of the compressed matrix.

    ``x_ref``   : (bm, K) activation stripe (resident across slots).
    ``val_ref`` : (1, max_blocks, bh, bw) nonzero tiles of block-row j.
    ``idx_ref`` : (1, max_blocks) block-column index per slot, -1 = pad.

    The slot loop is a ``fori_loop`` with a dynamic-slice load of the
    activation stripe — this is the HBM→VMEM gather schedule that replaces
    the OpenCL kernel's ``Cmat_row_ptrs`` walk.
    """
    x = x_ref[...]

    def body(s, acc):
        j = idx_ref[0, s]
        valid = j >= 0
        jc = jnp.maximum(j, 0)
        # (bm, bw) stripe of activations for this block column.
        xs = jax.lax.dynamic_slice(x, (0, jc * bw), (x.shape[0], bw))
        blk = val_ref[0, s]  # (bh, bw)
        contrib = jnp.dot(xs, blk.T, preferred_element_type=jnp.float32)
        return acc + jnp.where(valid, contrib, 0.0)

    acc0 = jnp.zeros(o_ref.shape, jnp.float32)
    o_ref[...] = jax.lax.fori_loop(0, max_blocks, body, acc0)


def bsr_dxct(
    dmat: jnp.ndarray,
    values: jnp.ndarray,
    col_idx: jnp.ndarray,
    bm: int = DEF_BM,
) -> jnp.ndarray:
    """Compressed forward product ``Dmat @ Cmat'`` with Block-ELL storage.

    ``dmat``   : ``(B, K)`` dense activations.
    ``values`` : ``(n_block_rows, max_blocks, bh, bw)`` nonzero weight
                 tiles (block-row major — the BSR analogue of CSR ``data``).
    ``col_idx``: ``(n_block_rows, max_blocks)`` int32 block-column of each
                 slot, ``-1`` for padding (analogue of CSR ``indices``).
    Returns ``(B, n_block_rows * bh)``.
    """
    b, k = dmat.shape
    n_br, max_blocks, bh, bw = values.shape
    assert k % bw == 0, f"K={k} not a multiple of block width {bw}"
    bm = min(bm, b)
    grid = (pl.cdiv(b, bm), n_br)
    return pl.pallas_call(
        functools.partial(_bsr_kernel, bh=bh, bw=bw, max_blocks=max_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((1, max_blocks, bh, bw), lambda i, j: (j, 0, 0, 0)),
            pl.BlockSpec((1, max_blocks), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bh), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_br * bh), jnp.float32),
        interpret=True,
    )(dmat, values, col_idx)


def dense_to_blockell(w, bh: int, bw: int, max_blocks: int | None = None):
    """Pack a dense ``(N, K)`` matrix into Block-ELL arrays.

    Returns ``(values, col_idx, density)`` where ``density`` is the
    fraction of block slots that are nonzero. Build-time helper (numpy
    semantics via jnp; used by tests and by ``aot.py`` when emitting
    compressed-inference artifacts).
    """
    import numpy as np

    w = np.asarray(w)
    n, k = w.shape
    assert n % bh == 0 and k % bw == 0, f"shape ({n},{k}) not tileable by ({bh},{bw})"
    n_br, n_bc = n // bh, k // bw
    blocks = w.reshape(n_br, bh, n_bc, bw).transpose(0, 2, 1, 3)  # (n_br, n_bc, bh, bw)
    nz = np.abs(blocks).sum(axis=(2, 3)) > 0  # (n_br, n_bc)
    per_row = nz.sum(axis=1)
    mb = int(per_row.max()) if max_blocks is None else max_blocks
    mb = max(mb, 1)
    values = np.zeros((n_br, mb, bh, bw), np.float32)
    col_idx = -np.ones((n_br, mb), np.int32)
    for i in range(n_br):
        cols = np.nonzero(nz[i])[0][:mb]
        for s, j in enumerate(cols):
            values[i, s] = blocks[i, j]
            col_idx[i, s] = j
    density = float(per_row.sum()) / (n_br * n_bc)
    return jnp.asarray(values), jnp.asarray(col_idx), density
