"""L2 step builders: the jittable graphs that ``aot.py`` lowers.

Each builder returns ``(fn, example_args, input_roles, output_roles)``:

* ``fn`` — a pure function over (nested tuples of) arrays; ``jax.jit``
  flattens arguments depth-first, so the manifest's flat role lists line
  up exactly with the lowered HLO parameter order the rust runtime feeds.
* ``example_args`` — ShapeDtypeStructs for ``.lower()``.
* roles — one ``{"role", "name", "shape", "dtype"}`` dict per flat leaf.

Step inventory (DESIGN.md §7): ``train_prox_adam``,
``train_prox_rmsprop``, ``train_prox_sgd``, ``train_masked``,
``train_mm``, ``eval``, ``infer``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import optim
from .models import common as C


def _loss_fn(model, params, x, y):
    logits = model.apply(list(params), x)
    return C.softmax_cross_entropy(logits, y)


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _param_structs(spec):
    return tuple(_sds(s["shape"]) for s in spec)


def _roles(spec, role, prefix=""):
    return [
        {"role": role, "name": prefix + s["name"], "shape": list(s["shape"]), "dtype": "f32"}
        for s in spec
    ]


def _scalar_role(role):
    return [{"role": role, "name": role, "shape": [], "dtype": "f32"}]


def _batch_roles(model, batch):
    c, h, w = model.INPUT_SHAPE
    return (
        [{"role": "x", "name": "x", "shape": [batch, c, h, w], "dtype": "f32"}],
        [{"role": "y", "name": "y", "shape": [batch], "dtype": "i32"}],
    )


def _batch_structs(model, batch):
    c, h, w = model.INPUT_SHAPE
    return _sds((batch, c, h, w)), _sds((batch,), jnp.int32)


def build_train_prox_adam(model, spec, batch):
    prunable = tuple(s["prunable"] for s in spec)

    def fn(params, m, v, t, x, y, lam, lr):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(model, p, x, y))(params)
        p2, m2, v2, t2 = optim.prox_adam(params, grads, m, v, t, prunable, lam, lr)
        return tuple(p2), tuple(m2), tuple(v2), t2, loss

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, ps, ps, _sds(()), xs, ys, _sds(()), _sds(()))
    xr, yr = _batch_roles(model, batch)
    in_roles = (
        _roles(spec, "param")
        + _roles(spec, "opt_m", "m:")
        + _roles(spec, "opt_v", "v:")
        + _scalar_role("opt_t")
        + xr + yr
        + _scalar_role("lambda")
        + _scalar_role("lr")
    )
    out_roles = (
        _roles(spec, "param")
        + _roles(spec, "opt_m", "m:")
        + _roles(spec, "opt_v", "v:")
        + _scalar_role("opt_t")
        + _scalar_role("loss")
    )
    return fn, args, in_roles, out_roles


def build_train_prox_rmsprop(model, spec, batch):
    prunable = tuple(s["prunable"] for s in spec)

    def fn(params, v, x, y, lam, lr):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(model, p, x, y))(params)
        p2, v2 = optim.prox_rmsprop(params, grads, v, prunable, lam, lr)
        return tuple(p2), tuple(v2), loss

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, ps, xs, ys, _sds(()), _sds(()))
    xr, yr = _batch_roles(model, batch)
    in_roles = (
        _roles(spec, "param") + _roles(spec, "opt_v", "v:")
        + xr + yr + _scalar_role("lambda") + _scalar_role("lr")
    )
    out_roles = _roles(spec, "param") + _roles(spec, "opt_v", "v:") + _scalar_role("loss")
    return fn, args, in_roles, out_roles


def build_train_prox_sgd(model, spec, batch):
    prunable = tuple(s["prunable"] for s in spec)

    def fn(params, x, y, lam, lr):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(model, p, x, y))(params)
        p2 = optim.prox_sgd(params, grads, prunable, lam, lr)
        return tuple(p2), loss

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, xs, ys, _sds(()), _sds(()))
    xr, yr = _batch_roles(model, batch)
    in_roles = _roles(spec, "param") + xr + yr + _scalar_role("lambda") + _scalar_role("lr")
    out_roles = _roles(spec, "param") + _scalar_role("loss")
    return fn, args, in_roles, out_roles


def build_train_masked(model, spec, batch):
    def fn(params, m, v, t, masks, x, y, lr):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(model, p, x, y))(params)
        p2, m2, v2, t2 = optim.masked_adam(params, grads, m, v, t, masks, lr)
        return tuple(p2), tuple(m2), tuple(v2), t2, loss

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, ps, ps, _sds(()), ps, xs, ys, _sds(()))
    xr, yr = _batch_roles(model, batch)
    in_roles = (
        _roles(spec, "param")
        + _roles(spec, "opt_m", "m:")
        + _roles(spec, "opt_v", "v:")
        + _scalar_role("opt_t")
        + _roles(spec, "mask", "mask:")
        + xr + yr + _scalar_role("lr")
    )
    out_roles = (
        _roles(spec, "param")
        + _roles(spec, "opt_m", "m:")
        + _roles(spec, "opt_v", "v:")
        + _scalar_role("opt_t")
        + _scalar_role("loss")
    )
    return fn, args, in_roles, out_roles


def build_train_mm(model, spec, batch):
    prunable = tuple(s["prunable"] for s in spec)

    def fn(params, mom, theta, lag, x, y, mu, lr):
        loss, grads = jax.value_and_grad(lambda p: _loss_fn(model, p, x, y))(params)
        p2, mo2 = optim.mm_lstep(params, grads, mom, theta, lag, prunable, mu, lr)
        return tuple(p2), tuple(mo2), loss

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, ps, ps, ps, xs, ys, _sds(()), _sds(()))
    xr, yr = _batch_roles(model, batch)
    in_roles = (
        _roles(spec, "param")
        + _roles(spec, "opt_m", "mom:")
        + _roles(spec, "theta", "theta:")
        + _roles(spec, "lagrange", "lag:")
        + xr + yr + _scalar_role("mu") + _scalar_role("lr")
    )
    out_roles = (
        _roles(spec, "param") + _roles(spec, "opt_m", "mom:") + _scalar_role("loss")
    )
    return fn, args, in_roles, out_roles


def build_eval(model, spec, batch):
    def fn(params, x, y):
        logits = model.apply(list(params), x)
        loss = C.softmax_cross_entropy(logits, y)
        correct = C.correct_count(logits, y)
        return loss, correct

    ps = _param_structs(spec)
    xs, ys = _batch_structs(model, batch)
    args = (ps, xs, ys)
    xr, yr = _batch_roles(model, batch)
    in_roles = _roles(spec, "param") + xr + yr
    out_roles = _scalar_role("loss") + [
        {"role": "correct", "name": "correct", "shape": [], "dtype": "i32"}
    ]
    return fn, args, in_roles, out_roles


def build_infer(model, spec, batch):
    def fn(params, x):
        return model.apply(list(params), x)

    ps = _param_structs(spec)
    xs, _ = _batch_structs(model, batch)
    args = (ps, xs)
    xr, _ = _batch_roles(model, batch)
    in_roles = _roles(spec, "param") + xr
    out_roles = [
        {
            "role": "logits",
            "name": "logits",
            "shape": [batch, model.NUM_CLASSES],
            "dtype": "f32",
        }
    ]
    return fn, args, in_roles, out_roles


BUILDERS = {
    "train_prox_adam": build_train_prox_adam,
    "train_prox_rmsprop": build_train_prox_rmsprop,
    "train_prox_sgd": build_train_prox_sgd,
    "train_masked": build_train_masked,
    "train_mm": build_train_mm,
    "eval": build_eval,
    "infer": build_infer,
}
