"""Build-time compile package: L1 Pallas kernels + L2 JAX graphs + AOT.

Nothing in this package is imported at runtime; ``aot.py`` lowers
everything to HLO text under ``artifacts/`` once (``make artifacts``) and
the rust coordinator is self-contained afterwards.
"""
