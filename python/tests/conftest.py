"""Shared pytest fixtures for the compile-path test suite."""

import sys
from pathlib import Path

import numpy as np
import pytest

# Make `compile` importable when pytest is invoked from python/ or repo root.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)
