"""Shared pytest fixtures for the compile-path test suite."""

import sys
from pathlib import Path

import numpy as np
import pytest

# Make `compile` importable when pytest is invoked from python/ or repo root.
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


# ---------------------------------------------------------------------------
# Optional-hypothesis shim: property sweeps skip (rather than error at
# collection) on minimal images without the `hypothesis` package. Test
# modules fall back to `from conftest import given, settings, st`.
# ---------------------------------------------------------------------------


def given(*_args, **_kwargs):
    def deco(_fn):
        return pytest.mark.skip(reason="hypothesis not installed")(_fn)

    return deco


def settings(*_args, **_kwargs):
    def deco(fn):
        return fn

    return deco


class _StrategyStub:
    """Stands in for `hypothesis.strategies`; strategies are never drawn
    because `given` skips the test before it runs."""

    def __getattr__(self, _name):
        def strategy(*_args, **_kwargs):
            return None

        return strategy


st = _StrategyStub()
