"""Optimizer math vs hand-rolled numpy references.

Validates Algorithms 1-2 from the paper (Prox-RMSProp, Prox-ADAM) and the
baseline updates (masked ADAM for debias/retrain, MM L-step) against
independent numpy implementations written straight from the paper's
pseudocode.
"""

import numpy as np
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal CI images: skip the sweeps, keep the rest
    from conftest import given, settings, st

from compile import optim

F32 = np.float32


def np_soft_threshold(x, t):
    return np.sign(x) * np.maximum(np.abs(x) - t, 0.0)


def np_prox_rmsprop(w, g, v, lam, lr, beta=optim.RMSPROP_BETA, eps=optim.EPS):
    """Algorithm 1, transcribed from the paper."""
    v2 = beta * v + (1 - beta) * g * g
    w2 = w - lr * g / (np.sqrt(v2) + eps)
    return np_soft_threshold(w2, lr * lam), v2


def np_prox_adam(w, g, m, v, t, lam, lr, b1=optim.ADAM_BETA1, b2=optim.ADAM_BETA2, eps=optim.EPS):
    """Algorithm 2, transcribed from the paper."""
    t2 = t + 1
    m2 = b1 * m + (1 - b1) * g
    v2 = b2 * v + (1 - b2) * g * g
    mhat = m2 / (1 - b1**t2)
    vhat = v2 / (1 - b2**t2)
    w2 = w - lr * mhat / (np.sqrt(vhat) + eps)
    return np_soft_threshold(w2, lr * lam), m2, v2, t2


def _leaves(rng, shapes, scale=1.0):
    return [jnp.asarray((rng.standard_normal(s) * scale).astype(F32)) for s in shapes]


SHAPES = [(5, 7), (20,), (3, 4, 2, 2)]


class TestProxSGD:
    def test_matches_reference(self, rng):
        w = _leaves(rng, SHAPES)
        g = _leaves(rng, SHAPES, 0.1)
        out = optim.prox_sgd(w, g, [True] * 3, 0.05, 0.1)
        for wi, gi, oi in zip(w, g, out):
            want = np_soft_threshold(np.asarray(wi) - 0.1 * np.asarray(gi), 0.1 * 0.05)
            np.testing.assert_allclose(oi, want, rtol=1e-5, atol=1e-6)

    def test_nonprunable_skips_prox(self, rng):
        w = _leaves(rng, [(6, 6)])
        g = _leaves(rng, [(6, 6)], 0.1)
        out = optim.prox_sgd(w, g, [False], 10.0, 0.1)  # huge lambda
        want = np.asarray(w[0]) - 0.1 * np.asarray(g[0])
        np.testing.assert_allclose(out[0], want, rtol=1e-6)


class TestProxRMSProp:
    def test_matches_reference(self, rng):
        w = _leaves(rng, SHAPES)
        g = _leaves(rng, SHAPES, 0.5)
        v = _leaves(rng, SHAPES, 0.0)
        p2, v2 = optim.prox_rmsprop(w, g, v, [True] * 3, 0.02, 0.01)
        for wi, gi, vi, pi, v2i in zip(w, g, v, p2, v2):
            pw, vw = np_prox_rmsprop(np.asarray(wi), np.asarray(gi), np.asarray(vi), 0.02, 0.01)
            np.testing.assert_allclose(pi, pw, rtol=1e-5, atol=1e-6)
            np.testing.assert_allclose(v2i, vw, rtol=1e-5, atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        lam=st.floats(0.0, 1.0),
        lr=st.floats(1e-5, 0.5),
        steps=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_multi_step(self, lam, lr, steps, seed):
        r = np.random.default_rng(seed)
        w = [jnp.asarray(r.standard_normal((4, 6)).astype(F32))]
        v = [jnp.zeros((4, 6), F32)]
        wn, vn = np.asarray(w[0]).copy(), np.zeros((4, 6), F32)
        for _ in range(steps):
            g = [jnp.asarray(r.standard_normal((4, 6)).astype(F32))]
            w, v = optim.prox_rmsprop(w, g, v, [True], lam, lr)
            wn, vn = np_prox_rmsprop(wn, np.asarray(g[0]), vn, lam, lr)
        np.testing.assert_allclose(w[0], wn, rtol=1e-4, atol=1e-5)


class TestProxAdam:
    def test_matches_reference_multistep(self, rng):
        shapes = SHAPES
        w = _leaves(rng, shapes)
        m = [jnp.zeros(s, F32) for s in shapes]
        v = [jnp.zeros(s, F32) for s in shapes]
        t = jnp.float32(0.0)
        wn = [np.asarray(x).copy() for x in w]
        mn = [np.zeros(s, F32) for s in shapes]
        vn = [np.zeros(s, F32) for s in shapes]
        tn = 0
        for _ in range(4):
            g = _leaves(rng, shapes, 0.3)
            w, m, v, t = optim.prox_adam(w, g, m, v, t, [True] * 3, 0.03, 0.002)
            for i in range(3):
                wn[i], mn[i], vn[i], _ = np_prox_adam(
                    wn[i], np.asarray(g[i]), mn[i], vn[i], tn, 0.03, 0.002
                )
            tn += 1
        assert float(t) == 4.0
        for i in range(3):
            np.testing.assert_allclose(w[i], wn[i], rtol=1e-4, atol=1e-5)

    def test_produces_exact_zeros(self, rng):
        w = _leaves(rng, [(50, 50)], scale=0.01)
        g = _leaves(rng, [(50, 50)], scale=0.01)
        m = [jnp.zeros((50, 50), F32)]
        v = [jnp.zeros((50, 50), F32)]
        p2, *_ = optim.prox_adam(w, g, m, v, jnp.float32(0), [True], 5.0, 0.01)
        out = np.asarray(p2[0])
        assert (out == 0).mean() > 0.5  # lam*lr = 0.05 >> weight scale 0.01

    def test_lambda_zero_is_plain_adam(self, rng):
        """λ=0 ⇒ no weight is zeroed (prox is identity)."""
        w = _leaves(rng, [(30, 30)])
        g = _leaves(rng, [(30, 30)], 0.1)
        m = [jnp.zeros((30, 30), F32)]
        v = [jnp.zeros((30, 30), F32)]
        p2, *_ = optim.prox_adam(w, g, m, v, jnp.float32(0), [True], 0.0, 0.01)
        assert (np.asarray(p2[0]) == 0).sum() == 0

    def test_monotone_compression_in_lambda(self, rng):
        """Higher λ ⇒ at least as many zeros after one step (Section 4.2)."""
        w = _leaves(rng, [(100, 100)], scale=0.05)
        g = _leaves(rng, [(100, 100)], scale=0.05)
        m = [jnp.zeros((100, 100), F32)]
        v = [jnp.zeros((100, 100), F32)]
        zeros = []
        for lam in [0.1, 1.0, 10.0]:
            p2, *_ = optim.prox_adam(w, g, m, v, jnp.float32(0), [True], lam, 0.01)
            zeros.append(int((np.asarray(p2[0]) == 0).sum()))
        assert zeros[0] <= zeros[1] <= zeros[2]


class TestMaskedAdam:
    def test_zeros_stay_zero(self, rng):
        shapes = [(20, 20)]
        w0 = _leaves(rng, shapes)
        mask = [jnp.asarray((rng.random(shapes[0]) < 0.4).astype(F32))]
        w = [w0[0] * mask[0]]
        m = [jnp.zeros(shapes[0], F32)]
        v = [jnp.zeros(shapes[0], F32)]
        t = jnp.float32(0)
        for _ in range(3):
            g = _leaves(rng, shapes, 0.5)
            w, m, v, t = optim.masked_adam(w, g, m, v, t, mask, 0.01)
        out = np.asarray(w[0])
        assert (out[np.asarray(mask[0]) == 0] == 0.0).all()

    def test_all_ones_mask_equals_adam_with_zero_lambda(self, rng):
        shapes = [(10, 10)]
        w = _leaves(rng, shapes)
        g = _leaves(rng, shapes, 0.2)
        m = [jnp.zeros(shapes[0], F32)]
        v = [jnp.zeros(shapes[0], F32)]
        ones = [jnp.ones(shapes[0], F32)]
        a, am, av, _ = optim.masked_adam(w, g, m, v, jnp.float32(0), ones, 0.01)
        b, bm, bv, _ = optim.prox_adam(w, g, m, v, jnp.float32(0), [True], 0.0, 0.01)
        np.testing.assert_allclose(a[0], b[0], rtol=1e-6)
        np.testing.assert_allclose(av[0], bv[0], rtol=1e-6)

    def test_masked_gradients_accumulate_no_momentum(self, rng):
        shapes = [(8, 8)]
        w = _leaves(rng, shapes)
        g = _leaves(rng, shapes, 1.0)
        m = [jnp.zeros(shapes[0], F32)]
        v = [jnp.zeros(shapes[0], F32)]
        zeros_mask = [jnp.zeros(shapes[0], F32)]
        _, m2, v2, _ = optim.masked_adam(w, g, m, v, jnp.float32(0), zeros_mask, 0.01)
        assert (np.asarray(m2[0]) == 0).all() and (np.asarray(v2[0]) == 0).all()


class TestMMLStep:
    def test_pull_toward_theta(self, rng):
        """With zero loss-gradient and λ=0, the L-step pulls w toward θ."""
        w = [jnp.ones((6, 6), F32) * 2.0]
        g = [jnp.zeros((6, 6), F32)]
        mom = [jnp.zeros((6, 6), F32)]
        theta = [jnp.zeros((6, 6), F32)]
        lag = [jnp.zeros((6, 6), F32)]
        w2, _ = optim.mm_lstep(w, g, mom, theta, lag, [True], mu=1.0, lr=0.1)
        assert (np.asarray(w2[0]) < 2.0).all()

    def test_matches_reference(self, rng):
        w = _leaves(rng, [(5, 5)])
        g = _leaves(rng, [(5, 5)], 0.3)
        mom = _leaves(rng, [(5, 5)], 0.1)
        theta = _leaves(rng, [(5, 5)])
        lag = _leaves(rng, [(5, 5)], 0.05)
        mu, lr = 0.7, 0.02
        w2, mo2 = optim.mm_lstep(w, g, mom, theta, lag, [True], mu, lr)
        g_aug = np.asarray(g[0]) + mu * (np.asarray(w[0]) - np.asarray(theta[0])) - np.asarray(lag[0])
        mo_want = optim.MM_MOMENTUM * np.asarray(mom[0]) + g_aug
        w_want = np.asarray(w[0]) - lr * mo_want
        np.testing.assert_allclose(mo2[0], mo_want, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(w2[0], w_want, rtol=1e-5, atol=1e-6)

    def test_nonprunable_gets_plain_sgd(self, rng):
        w = _leaves(rng, [(4, 4)])
        g = _leaves(rng, [(4, 4)], 0.2)
        mom = [jnp.zeros((4, 4), F32)]
        theta = [jnp.ones((4, 4), F32) * 100]  # would dominate if applied
        lag = [jnp.zeros((4, 4), F32)]
        w2, _ = optim.mm_lstep(w, g, mom, theta, lag, [False], 1.0, 0.1)
        want = np.asarray(w[0]) - 0.1 * np.asarray(g[0])
        np.testing.assert_allclose(w2[0], want, rtol=1e-5)
