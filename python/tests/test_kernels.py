"""L1 Pallas kernels vs the pure-jnp oracles in ref.py.

This is the CORE correctness signal for the compute layer: every kernel
that lowers into the AOT artifacts is pinned here, including hypothesis
sweeps over shapes, thresholds, and sparsity patterns.
"""

import numpy as np
import pytest
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:  # minimal CI images: skip the sweeps, keep the rest
    from conftest import given, settings, st

from compile.kernels import prox, ref, spmm

F32 = np.float32


def _arr(rng, *shape, scale=1.0):
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(F32))


# ---------------------------------------------------------------------------
# prox soft-threshold kernel (paper Figure 4)
# ---------------------------------------------------------------------------


class TestProxKernel:
    def test_matches_oracle_2d(self, rng):
        x = _arr(rng, 37, 53)
        np.testing.assert_allclose(
            prox.soft_threshold(x, 0.3), ref.soft_threshold(x, 0.3), rtol=1e-6
        )

    def test_matches_clip_formulation(self, rng):
        """sign·max form == the paper's Figure-4 min/max clip form."""
        x = _arr(rng, 64, 64)
        np.testing.assert_allclose(
            ref.soft_threshold(x, 0.2),
            ref.soft_threshold_clip_form(x, 0.2),
            rtol=1e-6,
        )

    @pytest.mark.parametrize("shape", [(1,), (7,), (5, 3), (20, 1, 5, 5), (128, 800)])
    def test_any_rank(self, rng, shape):
        x = _arr(rng, *shape)
        np.testing.assert_allclose(
            prox.soft_threshold(x, 0.1), ref.soft_threshold(x, 0.1), rtol=1e-6
        )

    def test_zero_threshold_is_identity(self, rng):
        x = _arr(rng, 16, 16)
        np.testing.assert_allclose(prox.soft_threshold(x, 0.0), x, rtol=1e-7)

    def test_large_threshold_kills_everything(self, rng):
        x = _arr(rng, 16, 16)
        out = np.asarray(prox.soft_threshold(x, 1e6))
        assert (out == 0).all()

    def test_produces_exact_zeros(self, rng):
        """Values inside the threshold band become EXACT zeros (the whole
        point of the proximal mechanism — Section 2.2)."""
        x = _arr(rng, 32, 32, scale=0.1)
        out = np.asarray(prox.soft_threshold(x, 0.15))
        inside = np.abs(np.asarray(x)) <= 0.15
        assert inside.any()
        assert (out[inside] == 0.0).all()

    def test_sign_preservation(self, rng):
        x = _arr(rng, 64, 64)
        out = np.asarray(prox.soft_threshold(x, 0.2))
        nz = out != 0
        assert (np.sign(out[nz]) == np.sign(np.asarray(x)[nz])).all()

    def test_shrinkage_magnitude(self, rng):
        """|prox(x)| = max(|x| - t, 0) elementwise."""
        x = _arr(rng, 40, 40)
        out = np.asarray(prox.soft_threshold(x, 0.25))
        want = np.maximum(np.abs(np.asarray(x)) - 0.25, 0.0)
        np.testing.assert_allclose(np.abs(out), want, rtol=1e-6, atol=1e-7)

    @settings(max_examples=30, deadline=None)
    @given(
        rows=st.integers(1, 300),
        cols=st.integers(1, 70),
        thresh=st.floats(0.0, 2.0),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes(self, rows, cols, thresh, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.standard_normal((rows, cols)).astype(F32))
        np.testing.assert_allclose(
            prox.soft_threshold(x, thresh),
            ref.soft_threshold(x, thresh),
            rtol=1e-5,
            atol=1e-6,
        )

    def test_nonexpansive(self, rng):
        """prox of a convex function is 1-Lipschitz: |prox(a)-prox(b)| <= |a-b|."""
        a, b = _arr(rng, 50, 50), _arr(rng, 50, 50)
        pa = np.asarray(prox.soft_threshold(a, 0.3))
        pb = np.asarray(prox.soft_threshold(b, 0.3))
        assert np.linalg.norm(pa - pb) <= np.linalg.norm(np.asarray(a - b)) + 1e-5

    def test_idempotent_on_fixed_points(self, rng):
        """Thresholding an already-thresholded array shrinks further by t —
        but prox with t=0 of a sparse array is the array (fixed point)."""
        x = _arr(rng, 30, 30)
        once = prox.soft_threshold(x, 0.5)
        np.testing.assert_allclose(prox.soft_threshold(once, 0.0), once, rtol=1e-7)


# ---------------------------------------------------------------------------
# dense × compressed' and dense × compressed (paper Figures 2-3)
# ---------------------------------------------------------------------------


class TestMatmulKernels:
    @pytest.mark.parametrize(
        "b,n,k",
        [(1, 1, 1), (4, 7, 9), (33, 41, 70), (128, 500, 800), (16, 10, 784), (64, 256, 1024)],
    )
    def test_dxct(self, rng, b, n, k):
        d, c = _arr(rng, b, k), _arr(rng, n, k)
        np.testing.assert_allclose(
            spmm.dxct(d, c), ref.dense_x_compressed_t(d, c), rtol=2e-4, atol=2e-4
        )

    @pytest.mark.parametrize(
        "b,n,k", [(1, 1, 1), (4, 7, 9), (33, 41, 70), (128, 500, 800), (64, 256, 1024)]
    )
    def test_dxc(self, rng, b, n, k):
        g, c = _arr(rng, b, n), _arr(rng, n, k)
        np.testing.assert_allclose(
            spmm.dxc(g, c), ref.dense_x_compressed(g, c), rtol=2e-4, atol=2e-4
        )

    def test_transpose_identity(self, rng):
        """(D×C')' == C×D' — the ViennaCL workaround the paper replaces."""
        d, c = _arr(rng, 24, 48), _arr(rng, 12, 48)
        lhs = np.asarray(spmm.dxct(d, c)).T
        rhs = np.asarray(c @ d.T)
        np.testing.assert_allclose(lhs, rhs, rtol=2e-4, atol=2e-4)

    def test_sparse_operand(self, rng):
        """Kernels are exact when the compressed operand is mostly zeros
        (the production regime: prox-trained weights)."""
        d = _arr(rng, 32, 200)
        c = np.asarray(_arr(rng, 60, 200)).copy()
        c[np.abs(c) < 1.2] = 0.0  # ~77% zeros
        c = jnp.asarray(c)
        np.testing.assert_allclose(
            spmm.dxct(d, c), ref.dense_x_compressed_t(d, c), rtol=2e-4, atol=2e-4
        )

    def test_zero_matrix(self, rng):
        d = _arr(rng, 8, 16)
        c = jnp.zeros((4, 16), F32)
        assert (np.asarray(spmm.dxct(d, c)) == 0).all()

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 40),
        n=st.integers(1, 40),
        k=st.integers(1, 600),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_dxct(self, b, n, k, seed):
        r = np.random.default_rng(seed)
        d = jnp.asarray(r.standard_normal((b, k)).astype(F32))
        c = jnp.asarray(r.standard_normal((n, k)).astype(F32))
        np.testing.assert_allclose(
            spmm.dxct(d, c), ref.dense_x_compressed_t(d, c), rtol=5e-4, atol=5e-4
        )

    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 40),
        n=st.integers(1, 600),
        k=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_dxc(self, b, n, k, seed):
        r = np.random.default_rng(seed)
        g = jnp.asarray(r.standard_normal((b, n)).astype(F32))
        c = jnp.asarray(r.standard_normal((n, k)).astype(F32))
        np.testing.assert_allclose(
            spmm.dxc(g, c), ref.dense_x_compressed(g, c), rtol=5e-4, atol=5e-4
        )

    def test_custom_block_sizes(self, rng):
        d, c = _arr(rng, 100, 300), _arr(rng, 90, 300)
        for bm, bn, bk in [(32, 32, 64), (128, 128, 512), (8, 16, 300)]:
            np.testing.assert_allclose(
                spmm.dxct(d, c, bm=bm, bn=bn, bk=bk),
                ref.dense_x_compressed_t(d, c),
                rtol=2e-4,
                atol=2e-4,
            )


# ---------------------------------------------------------------------------
# Block-ELL compressed kernel
# ---------------------------------------------------------------------------


def _sparse_blocks(rng, n, k, bh, bw, keep=0.3):
    """Dense matrix whose nonzeros come in whole (bh, bw) blocks."""
    n_br, n_bc = n // bh, k // bw
    w = np.zeros((n, k), F32)
    for i in range(n_br):
        for j in range(n_bc):
            if rng.random() < keep:
                w[i * bh : (i + 1) * bh, j * bw : (j + 1) * bw] = rng.standard_normal(
                    (bh, bw)
                )
    return w


class TestBlockEllKernel:
    @pytest.mark.parametrize("bh,bw", [(8, 16), (16, 16), (4, 32)])
    def test_roundtrip_to_dense(self, rng, bh, bw):
        w = _sparse_blocks(rng, 64, 128, bh, bw)
        vals, idx, density = spmm.dense_to_blockell(w, bh, bw)
        back = np.asarray(ref.bsr_to_dense(vals, idx, 128 // bw))
        np.testing.assert_allclose(back, w, rtol=1e-6)
        assert 0.0 <= density <= 1.0

    def test_matmul_matches_dense(self, rng):
        w = _sparse_blocks(rng, 64, 128, 8, 16, keep=0.4)
        vals, idx, _ = spmm.dense_to_blockell(w, 8, 16)
        d = _arr(rng, 24, 128)
        got = spmm.bsr_dxct(d, vals, idx)
        want = np.asarray(d) @ w.T
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_all_zero_matrix(self, rng):
        w = np.zeros((32, 64), F32)
        vals, idx, density = spmm.dense_to_blockell(w, 8, 16)
        assert density == 0.0
        d = _arr(rng, 8, 64)
        assert (np.asarray(spmm.bsr_dxct(d, vals, idx)) == 0).all()

    def test_padding_slots_ignored(self, rng):
        """Rows with fewer blocks than max_blocks must not pollute output."""
        w = np.zeros((16, 64), F32)
        w[0:8, 0:16] = 1.0  # block-row 0: 1 block; block-row 1: 3 blocks
        w[8:16, 0:48] = 2.0
        vals, idx, _ = spmm.dense_to_blockell(w, 8, 16)
        assert (np.asarray(idx)[0, 1:] == -1).all()
        d = _arr(rng, 4, 64)
        np.testing.assert_allclose(
            spmm.bsr_dxct(d, vals, idx), np.asarray(d) @ w.T, rtol=2e-4, atol=2e-4
        )

    @settings(max_examples=15, deadline=None)
    @given(
        n_br=st.integers(1, 6),
        n_bc=st.integers(1, 6),
        keep=st.floats(0.1, 0.9),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_blocks(self, n_br, n_bc, keep, seed):
        r = np.random.default_rng(seed)
        bh, bw = 8, 16
        w = _sparse_blocks(r, n_br * bh, n_bc * bw, bh, bw, keep)
        vals, idx, _ = spmm.dense_to_blockell(w, bh, bw)
        d = jnp.asarray(r.standard_normal((8, n_bc * bw)).astype(F32))
        np.testing.assert_allclose(
            spmm.bsr_dxct(d, vals, idx), np.asarray(d) @ w.T, rtol=5e-4, atol=5e-4
        )


# ---------------------------------------------------------------------------
# masked update oracle (used by the debias/retrain artifacts)
# ---------------------------------------------------------------------------


class TestMaskedUpdate:
    def test_mask_freezes_zeros(self, rng):
        w = _arr(rng, 10, 10)
        step = _arr(rng, 10, 10, scale=0.1)
        mask = jnp.asarray((rng.random((10, 10)) < 0.5).astype(F32))
        out = np.asarray(ref.masked_update(w, step, mask))
        assert (out[np.asarray(mask) == 0] == 0).all()

    def test_unmasked_positions_update(self, rng):
        w = _arr(rng, 10, 10)
        step = _arr(rng, 10, 10, scale=0.1)
        mask = jnp.ones((10, 10), F32)
        np.testing.assert_allclose(ref.masked_update(w, step, mask), w - step, rtol=1e-6)
