"""AOT path: lowering produces valid HLO text, manifests are consistent,
and the training graphs decrease loss / create exact zeros when executed.
"""

import json
from pathlib import Path

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, steps as steps_mod
from compile.models import REGISTRY

F32 = np.float32
ARTIFACTS = Path(__file__).resolve().parents[2] / "artifacts"


def _entry_param_count(hlo: str) -> int:
    """Count ``parameter(i)`` instructions inside the ENTRY computation.

    ``parameter(i)`` index ``i`` equals the flat argument position — the
    identity the rust runtime relies on (textual order is arbitrary).
    """
    lines = hlo.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    n = 0
    for l in lines[start + 1 :]:
        if l.startswith("}"):
            break
        if " parameter(" in l:
            n += 1
    return n


class TestLowering:
    @pytest.mark.parametrize("step", sorted(steps_mod.BUILDERS))
    def test_mlp_all_steps_lower(self, step):
        model = REGISTRY["mlp"]
        _, spec = model.init(0)
        hlo, in_roles, out_roles = aot.lower_one(model, spec, step, batch=8)
        assert hlo.startswith("HloModule"), hlo[:50]
        assert len(in_roles) > 0 and len(out_roles) > 0

    def test_role_count_matches_hlo_params(self):
        """Flat role list must line up 1:1 with lowered HLO parameters —
        this is the contract the rust runtime depends on."""
        model = REGISTRY["mlp"]
        _, spec = model.init(0)
        hlo, in_roles, _ = aot.lower_one(model, spec, "train_prox_adam", batch=8)
        assert _entry_param_count(hlo) == len(in_roles)

    def test_scalar_roles_are_rank0(self):
        model = REGISTRY["mlp"]
        _, spec = model.init(0)
        _, in_roles, out_roles = aot.lower_one(model, spec, "train_prox_adam", batch=8)
        for r in in_roles:
            if r["role"] in ("lambda", "lr", "opt_t"):
                assert r["shape"] == []
        assert out_roles[-1]["role"] == "loss" and out_roles[-1]["shape"] == []


class TestTrainingBehaviour:
    def test_prox_adam_loss_decreases_and_sparsifies(self, rng):
        model = REGISTRY["mlp"]
        params, spec = model.init(0)
        fn, _, _, _ = steps_mod.build_train_prox_adam(model, spec, 32)
        jfn = jax.jit(fn)
        x = jnp.asarray(rng.standard_normal((32, 1, 28, 28)).astype(F32))
        y = jnp.asarray(rng.integers(0, 10, 32).astype(np.int32))
        ps = tuple(jnp.asarray(p) for p in params)
        zs = tuple(jnp.zeros_like(p) for p in params)
        m, v, t = zs, zs, jnp.float32(0)
        losses = []
        for _ in range(12):
            ps, m, v, t, loss = jfn(ps, m, v, t, x, y, jnp.float32(5.0), jnp.float32(5e-3))
            losses.append(float(loss))
        assert losses[-1] < losses[0]
        zeros = sum(int((np.asarray(p) == 0).sum()) for p in ps)
        assert zeros > 1000  # prox writes exact zeros while training

    def test_rmsprop_runs(self, rng):
        model = REGISTRY["mlp"]
        params, spec = model.init(0)
        fn, _, _, _ = steps_mod.build_train_prox_rmsprop(model, spec, 16)
        jfn = jax.jit(fn)
        x = jnp.asarray(rng.standard_normal((16, 1, 28, 28)).astype(F32))
        y = jnp.asarray(rng.integers(0, 10, 16).astype(np.int32))
        ps = tuple(jnp.asarray(p) for p in params)
        v = tuple(jnp.zeros_like(p) for p in params)
        ps, v, loss = jfn(ps, v, x, y, jnp.float32(0.01), jnp.float32(1e-3))
        assert np.isfinite(float(loss))

    def test_masked_step_never_resurrects_zeros(self, rng):
        model = REGISTRY["mlp"]
        params, spec = model.init(0)
        fn, _, _, _ = steps_mod.build_train_masked(model, spec, 16)
        jfn = jax.jit(fn)
        x = jnp.asarray(rng.standard_normal((16, 1, 28, 28)).astype(F32))
        y = jnp.asarray(rng.integers(0, 10, 16).astype(np.int32))
        masks = []
        ps = []
        for p, s in zip(params, spec):
            if s["prunable"]:
                mk = (rng.random(p.shape) < 0.3).astype(F32)
            else:
                mk = np.ones(p.shape, F32)
            masks.append(jnp.asarray(mk))
            ps.append(jnp.asarray(p * mk))
        ps = tuple(ps)
        masks = tuple(masks)
        zs = tuple(jnp.zeros_like(p) for p in params)
        m, v, t = zs, zs, jnp.float32(0)
        for _ in range(5):
            ps, m, v, t, loss = jfn(ps, m, v, t, masks, x, y, jnp.float32(1e-3))
        for p, mk in zip(ps, masks):
            dead = np.asarray(mk) == 0
            assert (np.asarray(p)[dead] == 0).all()

    def test_eval_counts(self, rng):
        model = REGISTRY["mlp"]
        params, spec = model.init(0)
        fn, _, _, _ = steps_mod.build_eval(model, spec, 16)
        x = jnp.asarray(rng.standard_normal((16, 1, 28, 28)).astype(F32))
        y = jnp.asarray(rng.integers(0, 10, 16).astype(np.int32))
        loss, correct = jax.jit(fn)(tuple(jnp.asarray(p) for p in params), x, y)
        assert 0 <= int(correct) <= 16
        assert np.isfinite(float(loss))


@pytest.mark.skipif(not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first")
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        return json.loads((ARTIFACTS / "manifest.json").read_text())

    def test_all_models_listed(self, manifest):
        assert set(manifest["models"]) == set(REGISTRY)

    def test_artifact_files_exist(self, manifest):
        for entry in manifest["models"].values():
            for art in entry["artifacts"].values():
                assert (ARTIFACTS / art["file"]).exists(), art["file"]

    def test_param_counts(self, manifest):
        for name, entry in manifest["models"].items():
            params, spec = REGISTRY[name].init(0)
            assert entry["num_params"] == sum(p.size for p in params)
            assert entry["num_weights"] == sum(
                p.size for p, s in zip(params, spec) if s["prunable"]
            )

    def test_lenet_matches_paper_total(self, manifest):
        assert manifest["models"]["lenet"]["num_weights"] == 430_500

    def test_input_roles_match_hlo_arity(self, manifest):
        """Every artifact's input role list matches its HLO entry arity."""
        for entry in manifest["models"].values():
            for art in entry["artifacts"].values():
                text = (ARTIFACTS / art["file"]).read_text()
                assert _entry_param_count(text) == len(art["inputs"]), art["file"]
