"""Model zoo: shapes, parameter counts, init statistics, gradient checks."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.models import REGISTRY, get
from compile.models import common as C

F32 = np.float32


def _batch(rng, model, b=4):
    c, h, w = model.INPUT_SHAPE
    x = jnp.asarray(rng.standard_normal((b, c, h, w)).astype(F32))
    y = jnp.asarray(rng.integers(0, model.NUM_CLASSES, b).astype(np.int32))
    return x, y


class TestRegistry:
    def test_all_models_present(self):
        assert set(REGISTRY) == {"mlp", "lenet", "alexnet_s", "vgg_s", "resnet_s"}

    def test_get_unknown_raises(self):
        with pytest.raises(KeyError):
            get("inception_v9")


@pytest.mark.parametrize("name", sorted(REGISTRY))
class TestEveryModel:
    def test_spec_matches_params(self, name):
        model = REGISTRY[name]
        params, spec = model.init(0)
        assert len(params) == len(spec)
        for p, s in zip(params, spec):
            assert list(p.shape) == s["shape"], s["name"]
            assert p.dtype == np.float32

    def test_logits_shape(self, name, rng):
        model = REGISTRY[name]
        params, _ = model.init(0)
        x, _ = _batch(rng, model)
        logits = model.apply([jnp.asarray(p) for p in params], x)
        assert logits.shape == (4, model.NUM_CLASSES)
        assert np.isfinite(np.asarray(logits)).all()

    def test_deterministic_init(self, name):
        model = REGISTRY[name]
        p1, _ = model.init(7)
        p2, _ = model.init(7)
        for a, b in zip(p1, p2):
            np.testing.assert_array_equal(a, b)

    def test_seed_changes_init(self, name):
        model = REGISTRY[name]
        p1, _ = model.init(0)
        p2, _ = model.init(1)
        assert any(not np.array_equal(a, b) for a, b in zip(p1, p2))

    def test_biases_zero_and_nonprunable(self, name):
        model = REGISTRY[name]
        params, spec = model.init(0)
        for p, s in zip(params, spec):
            if s["kind"] in ("conv_b", "fc_b", "bn_bias"):
                assert not s["prunable"]
                assert (p == 0).all()

    def test_he_init_std(self, name):
        """Weight std ≈ sqrt(2/fan_in) for large leaves."""
        model = REGISTRY[name]
        params, spec = model.init(0)
        for p, s in zip(params, spec):
            if not s["prunable"] or p.size < 5000:
                continue
            if s["kind"] == "conv_w":
                fan_in = p.shape[1] * p.shape[2] * p.shape[3]
            else:
                fan_in = p.shape[1]
            want = np.sqrt(2.0 / fan_in)
            assert abs(p.std() - want) / want < 0.1, s["name"]

    def test_loss_grad_finite(self, name, rng):
        model = REGISTRY[name]
        params, _ = model.init(0)
        x, y = _batch(rng, model)
        ps = tuple(jnp.asarray(p) for p in params)

        def loss_fn(p):
            return C.softmax_cross_entropy(model.apply(list(p), x), y)

        loss, grads = jax.value_and_grad(loss_fn)(ps)
        assert np.isfinite(float(loss))
        for g in grads:
            assert np.isfinite(np.asarray(g)).all()

    def test_initial_loss_near_uniform(self, name, rng):
        """Fresh net ⇒ CE ≈ ln(num_classes)."""
        model = REGISTRY[name]
        params, _ = model.init(0)
        x, y = _batch(rng, model, b=8)
        loss = float(
            C.softmax_cross_entropy(model.apply([jnp.asarray(p) for p in params], x), y)
        )
        assert loss < 3 * np.log(model.NUM_CLASSES) + 1.0


class TestLeNetPaperSizes:
    """LeNet-5 must match the paper's Table A1 exactly."""

    def test_layer_weight_counts(self):
        _, spec = REGISTRY["lenet"].init(0)
        counts = {s["name"]: int(np.prod(s["shape"])) for s in spec if s["prunable"]}
        assert counts == {
            "conv1_w": 500,
            "conv2_w": 25_000,
            "fc1_w": 400_000,
            "fc2_w": 5_000,
        }

    def test_total_prunable(self):
        _, spec = REGISTRY["lenet"].init(0)
        total = sum(int(np.prod(s["shape"])) for s in spec if s["prunable"])
        assert total == 430_500  # Table A1 "Total Weights"


class TestFCThroughPaperKernels:
    def test_fc_gradient_check(self, rng):
        """Finite differences through the custom VJP (Figs. 2-3 kernels)."""
        x = jnp.asarray(rng.standard_normal((3, 5)).astype(F32))
        w = jnp.asarray(rng.standard_normal((4, 5)).astype(F32))

        def f(w_):
            return jnp.sum(C.fc_apply(x, w_) ** 2)

        g = np.asarray(jax.grad(f)(w))
        eps = 1e-3
        for idx in [(0, 0), (1, 3), (3, 4)]:
            wp = np.asarray(w).copy(); wp[idx] += eps
            wm = np.asarray(w).copy(); wm[idx] -= eps
            fd = (float(f(jnp.asarray(wp))) - float(f(jnp.asarray(wm)))) / (2 * eps)
            assert abs(fd - g[idx]) < 2e-1 * max(1.0, abs(fd)), idx

    def test_fc_x_gradient(self, rng):
        x = jnp.asarray(rng.standard_normal((2, 6)).astype(F32))
        w = jnp.asarray(rng.standard_normal((3, 6)).astype(F32))

        def f(x_):
            return jnp.sum(jnp.sin(C.fc_apply(x_, w)))

        g = np.asarray(jax.grad(f)(x))
        eps = 1e-3
        for idx in [(0, 0), (1, 5)]:
            xp = np.asarray(x).copy(); xp[idx] += eps
            xm = np.asarray(x).copy(); xm[idx] -= eps
            fd = (float(f(jnp.asarray(xp))) - float(f(jnp.asarray(xm)))) / (2 * eps)
            assert abs(fd - g[idx]) < 2e-1 * max(1.0, abs(fd)), idx


class TestCommonOps:
    def test_max_pool(self, rng):
        x = jnp.arange(16, dtype=jnp.float32).reshape(1, 1, 4, 4)
        out = np.asarray(C.max_pool(x))
        np.testing.assert_array_equal(out[0, 0], [[5, 7], [13, 15]])

    def test_batch_norm_normalizes(self, rng):
        x = jnp.asarray(rng.standard_normal((8, 3, 5, 5)).astype(F32) * 4 + 2)
        out = np.asarray(C.batch_norm(x, jnp.ones(3), jnp.zeros(3)))
        assert abs(out.mean()) < 1e-3
        assert abs(out.std() - 1.0) < 1e-2

    def test_softmax_ce_uniform(self):
        logits = jnp.zeros((4, 10), jnp.float32)
        y = jnp.asarray([0, 1, 2, 3], dtype=jnp.int32)
        assert abs(float(C.softmax_cross_entropy(logits, y)) - np.log(10)) < 1e-5

    def test_correct_count(self):
        logits = jnp.asarray(np.eye(4, 10, dtype=F32) * 5)
        y = jnp.asarray([0, 1, 2, 0], dtype=np.int32)
        assert int(C.correct_count(logits, y)) == 3
